//! Transport abstraction for the sampling protocol (DESIGN.md §12): the
//! same `GatherRequest`/`GatherResponse` messages flow either over
//! in-process mpsc channels ([`ChannelTransport`] — the deployment every
//! prior PR used) or over TCP/Unix-socket connections ([`SocketTransport`])
//! to partition servers running as separate `glisp serve` processes.
//!
//! The per-seed RNG contract (DESIGN.md §7/§9) is what makes this split
//! free: a server derives every sampled value from (partition seed, request
//! salt, seed index), none of which the transport touches, so a loopback
//! multi-process run is bit-identical to the in-process pool for any
//! (workers, shard_size) — asserted end-to-end in `tests/wire_service.rs`
//! and the CI wire job.
//!
//! Server side: [`serve_partition`] binds one listener per partition and
//! feeds the existing [`spawn_pool`] worker pool through the same mpsc
//! inbox the in-process service uses — pool workers cannot tell which
//! transport a shard arrived by. Each accepted connection gets one reader
//! thread (decodes frames, forwards gathers, answers control RPCs) and one
//! writer thread (drains the pool's responses back onto the socket); both
//! reuse per-connection scratch buffers, so steady-state encode/decode
//! does not allocate per request.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::graph::csr::VId;
use crate::graph::hetero::PartitionGraph;
use crate::sampling::request::{GatherRequest, GatherResponse, ServerMsg};
use crate::sampling::server::{spawn_pool, ServerStats};
use crate::sampling::wire::{
    encode_frame, read_frame, Frame, MembersInfo, StatsSnapshot,
};

/// One partition server endpoint, as seen by `SamplingClient` /
/// `SamplingService`. Implementations must deliver the response for every
/// accepted [`Transport::send_gather`] to the given reply sender, or make
/// the failure observable by dropping the sender (a hung-up channel is the
/// client's "server died mid-gather" signal — identical semantics in- and
/// cross-process).
pub trait Transport: Send + Sync {
    /// Partition this endpoint serves.
    fn part_id(&self) -> usize;

    /// Human-readable peer name for error messages: `"channel"` in-process,
    /// the socket address (e.g. `"tcp:127.0.0.1:4070"`) across the wire.
    fn peer(&self) -> &str;

    /// Submit one gather shard; its response (token echoed) arrives on
    /// `reply`.
    fn send_gather(&self, req: GatherRequest, reply: &Sender<GatherResponse>) -> Result<()>;

    /// Snapshot the server's workload counters.
    fn stats(&self) -> Result<StatsSnapshot>;

    /// Zero the server's workload counters.
    fn reset_stats(&self) -> Result<()>;

    /// The server's partition id, pool size and replicated vertex ids.
    fn members(&self) -> Result<MembersInfo>;

    /// Stop the server (all pool workers). Idempotence is not required —
    /// the service calls it once per endpoint.
    fn shutdown(&self) -> Result<()>;
}

// ---------------------------------------------------------------------------
// In-process endpoint
// ---------------------------------------------------------------------------

/// The classic in-process deployment: the endpoint IS the pool inbox, plus
/// direct handles on the shared stats/graph for control operations.
pub struct ChannelTransport {
    pub part_id: usize,
    pub inbox: Sender<ServerMsg>,
    pub stats: Arc<ServerStats>,
    pub graph: Arc<PartitionGraph>,
    pub workers: usize,
}

impl Transport for ChannelTransport {
    fn part_id(&self) -> usize {
        self.part_id
    }

    fn peer(&self) -> &str {
        "channel"
    }

    fn send_gather(&self, req: GatherRequest, reply: &Sender<GatherResponse>) -> Result<()> {
        self.inbox
            .send(ServerMsg::Gather(req, reply.clone()))
            .map_err(|_| {
                anyhow!(
                    "sampling server for partition {} (channel) hung up before the gather",
                    self.part_id
                )
            })
    }

    fn stats(&self) -> Result<StatsSnapshot> {
        Ok(StatsSnapshot::capture(self.part_id, &self.stats, self.graph.nbytes()))
    }

    fn reset_stats(&self) -> Result<()> {
        self.stats.reset();
        Ok(())
    }

    fn members(&self) -> Result<MembersInfo> {
        Ok(MembersInfo {
            part_id: self.part_id as u32,
            workers: self.workers as u32,
            ids: self.graph.global_id.to_vec(),
        })
    }

    fn shutdown(&self) -> Result<()> {
        // One Shutdown per pool member (each worker consumes exactly one).
        for _ in 0..self.workers.max(1) {
            let _ = self.inbox.send(ServerMsg::Shutdown);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Socket plumbing shared by client and server sides
// ---------------------------------------------------------------------------

/// A connected stream, TCP or Unix. Address syntax accepted everywhere a
/// peer is named: `unix:/path/to.sock`, `tcp:HOST:PORT`, or bare
/// `HOST:PORT` (TCP).
pub enum Conn {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl Conn {
    pub fn dial(addr: &str) -> Result<Conn> {
        if let Some(path) = addr.strip_prefix("unix:") {
            Ok(Conn::Unix(UnixStream::connect(path).with_context(|| {
                format!("connecting to sampling server at unix:{path}")
            })?))
        } else {
            let hostport = addr.strip_prefix("tcp:").unwrap_or(addr);
            Ok(Conn::Tcp(TcpStream::connect(hostport).with_context(|| {
                format!("connecting to sampling server at tcp:{hostport}")
            })?))
        }
    }

    /// An independently readable/writable handle on the same connection
    /// (read half / write half split).
    pub fn try_clone(&self) -> Result<Conn> {
        Ok(match self {
            Conn::Tcp(s) => Conn::Tcp(s.try_clone().context("cloning tcp stream")?),
            Conn::Unix(s) => Conn::Unix(s.try_clone().context("cloning unix stream")?),
        })
    }

    /// Disable Nagle batching on TCP (gather shards are latency-bound
    /// small writes); no-op for Unix sockets.
    fn set_low_latency(&self) {
        if let Conn::Tcp(s) = self {
            let _ = s.set_nodelay(true);
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            Conn::Unix(s) => s.flush(),
        }
    }
}

/// A bound listener, TCP or Unix. Binding `tcp:HOST:0` picks a free port;
/// [`Listener::local_addr`] reports the dialable address either way. A
/// stale Unix socket file at the requested path is removed before binding
/// (the standard daemon restart convention).
pub enum Listener {
    Tcp(TcpListener),
    Unix(UnixListener, PathBuf),
}

impl Listener {
    pub fn bind(addr: &str) -> Result<Listener> {
        if let Some(path) = addr.strip_prefix("unix:") {
            let pb = PathBuf::from(path);
            if pb.exists() {
                std::fs::remove_file(&pb)
                    .with_context(|| format!("removing stale socket {path}"))?;
            }
            Ok(Listener::Unix(
                UnixListener::bind(&pb).with_context(|| format!("binding unix:{path}"))?,
                pb,
            ))
        } else {
            let hostport = addr.strip_prefix("tcp:").unwrap_or(addr);
            Ok(Listener::Tcp(
                TcpListener::bind(hostport).with_context(|| format!("binding tcp:{hostport}"))?,
            ))
        }
    }

    /// The dialable address, in the same `tcp:`/`unix:` syntax `dial`
    /// accepts (with the real port when bound to port 0).
    pub fn local_addr(&self) -> Result<String> {
        Ok(match self {
            Listener::Tcp(l) => format!("tcp:{}", l.local_addr().context("tcp local_addr")?),
            Listener::Unix(_, p) => format!("unix:{}", p.display()),
        })
    }

    fn accept(&self) -> std::io::Result<Conn> {
        Ok(match self {
            Listener::Tcp(l) => Conn::Tcp(l.accept()?.0),
            Listener::Unix(l, _) => Conn::Unix(l.accept()?.0),
        })
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Unix(_, p) = self {
            let _ = std::fs::remove_file(p);
        }
    }
}

// ---------------------------------------------------------------------------
// Client side: SocketTransport
// ---------------------------------------------------------------------------

/// Write half of a client connection plus its reusable encode scratch and
/// the token counter — one lock covers all three, so token assignment and
/// frame write are atomic per request.
struct WriteHalf {
    conn: Conn,
    buf: Vec<u8>,
    next_token: u64,
}

/// Control-RPC replies routed off the shared reader thread. The `ctl`
/// mutex in [`SocketTransport`] admits one control RPC at a time, so the
/// next control frame received always belongs to the caller holding it.
enum CtlReply {
    Stats(StatsSnapshot),
    Members(MembersInfo),
    Ack,
}

/// A network client endpoint: one connection to one `glisp serve`
/// partition process, shared by every [`crate::sampling::SamplingClient`]
/// clone of a service (pipelined producers included — responses are
/// demultiplexed by token). All errors name the peer address and the
/// partition id, so a dead or unreachable fleet member is identifiable
/// from the message alone.
pub struct SocketTransport {
    peer: String,
    part_id: AtomicUsize,
    wr: Mutex<WriteHalf>,
    pending: Arc<Mutex<HashMap<u64, Sender<GatherResponse>>>>,
    /// Set by the reader thread on its way out. Ordering contract with
    /// `send_gather`: the reader STORES this before clearing `pending`,
    /// and a sender INSERTS into `pending` before loading it — so every
    /// interleaving either fails the send or gets its pending entry
    /// dropped, and no caller can wait on a token the dead reader will
    /// never deliver.
    closed: Arc<AtomicBool>,
    ctl: Mutex<Receiver<CtlReply>>,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl SocketTransport {
    /// Dial `addr` and fetch the peer's identity (partition id). The
    /// reader thread lives until the connection closes.
    pub fn connect(addr: &str) -> Result<Arc<SocketTransport>> {
        let conn = Conn::dial(addr)?;
        conn.set_low_latency();
        let rd = conn.try_clone()?;
        let pending: Arc<Mutex<HashMap<u64, Sender<GatherResponse>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let (ctl_tx, ctl_rx) = channel();
        let closed = Arc::new(AtomicBool::new(false));
        let t = Arc::new(SocketTransport {
            peer: addr.to_string(),
            part_id: AtomicUsize::new(usize::MAX),
            wr: Mutex::new(WriteHalf { conn, buf: Vec::new(), next_token: 1 }),
            pending: pending.clone(),
            closed: closed.clone(),
            ctl: Mutex::new(ctl_rx),
            reader: Mutex::new(None),
        });
        let handle = std::thread::spawn(move || {
            reader_loop(rd, pending, closed, ctl_tx);
        });
        *t.reader.lock().unwrap() = Some(handle);
        let info = t.members().with_context(|| format!("handshaking with {addr}"))?;
        t.part_id.store(info.part_id as usize, Ordering::Relaxed);
        Ok(t)
    }

    fn write_frame(&self, f: &Frame) -> Result<()> {
        let mut wr = self.wr.lock().unwrap();
        encode_frame(&mut wr.buf, f);
        let WriteHalf { conn, buf, .. } = &mut *wr;
        conn.write_all(buf).map_err(|e| {
            anyhow!(
                "partition {} at {}: write failed: {e}",
                self.part_id.load(Ordering::Relaxed),
                self.peer
            )
        })
    }

    /// One control request/reply round trip. Holding the `ctl` receiver
    /// lock serializes control RPCs per connection (gathers keep flowing
    /// concurrently — they are demultiplexed by token, not ordering).
    fn control(&self, f: Frame, what: &str) -> Result<CtlReply> {
        let rx = self.ctl.lock().unwrap();
        self.write_frame(&f)?;
        rx.recv().map_err(|_| {
            anyhow!(
                "partition {} at {}: connection closed awaiting {what}",
                self.part_id.load(Ordering::Relaxed),
                self.peer
            )
        })
    }
}

impl Transport for SocketTransport {
    fn part_id(&self) -> usize {
        self.part_id.load(Ordering::Relaxed)
    }

    fn peer(&self) -> &str {
        &self.peer
    }

    fn send_gather(&self, mut req: GatherRequest, reply: &Sender<GatherResponse>) -> Result<()> {
        let mut wr = self.wr.lock().unwrap();
        let token = wr.next_token;
        wr.next_token += 1;
        req.token = token;
        self.pending.lock().unwrap().insert(token, reply.clone());
        encode_frame(&mut wr.buf, &Frame::Gather(req));
        let WriteHalf { conn, buf, .. } = &mut *wr;
        if let Err(e) = conn.write_all(buf) {
            self.pending.lock().unwrap().remove(&token);
            bail!(
                "sampling server for partition {} at {}: gather write failed: {e}",
                self.part_id.load(Ordering::Relaxed),
                self.peer
            );
        }
        // The OS may happily buffer a write to a dead peer. If the reader
        // already exited (it clears `pending` AFTER setting `closed`, and
        // we inserted BEFORE this load), nobody will ever deliver this
        // token — fail the send instead of letting the caller wait on it.
        if self.closed.load(Ordering::SeqCst) {
            self.pending.lock().unwrap().remove(&token);
            bail!(
                "sampling server for partition {} at {}: connection closed before the gather",
                self.part_id.load(Ordering::Relaxed),
                self.peer
            );
        }
        Ok(())
    }

    fn stats(&self) -> Result<StatsSnapshot> {
        match self.control(Frame::Stats, "stats")? {
            CtlReply::Stats(s) => Ok(s),
            _ => bail!("partition {} at {}: unexpected stats reply", self.part_id(), self.peer),
        }
    }

    fn reset_stats(&self) -> Result<()> {
        match self.control(Frame::ResetStats, "reset-stats ack")? {
            CtlReply::Ack => Ok(()),
            _ => bail!("partition {} at {}: unexpected reset reply", self.part_id(), self.peer),
        }
    }

    fn members(&self) -> Result<MembersInfo> {
        match self.control(Frame::Members, "members")? {
            CtlReply::Members(m) => Ok(m),
            _ => bail!("partition {} at {}: unexpected members reply", self.part_id(), self.peer),
        }
    }

    fn shutdown(&self) -> Result<()> {
        match self.control(Frame::Shutdown, "shutdown ack")? {
            CtlReply::Ack => Ok(()),
            _ => bail!("partition {} at {}: unexpected shutdown reply", self.part_id(), self.peer),
        }
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        // Closing the write half is enough: the server sees EOF and tears
        // the connection down; our reader thread then exits on its own EOF.
        if let Some(h) = self.reader.lock().unwrap().take() {
            if let Ok(mut wr) = self.wr.lock() {
                let _ = match &mut wr.conn {
                    Conn::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
                    Conn::Unix(s) => s.shutdown(std::net::Shutdown::Both),
                };
            }
            let _ = h.join();
        }
    }
}

/// Client-side reader: demultiplex gather responses by token, forward
/// control replies to the (single) waiting control RPC. Exit drops every
/// pending reply sender, which is how in-flight `sample_one_hop` calls
/// observe a dead connection.
fn reader_loop(
    mut rd: Conn,
    pending: Arc<Mutex<HashMap<u64, Sender<GatherResponse>>>>,
    closed: Arc<AtomicBool>,
    ctl_tx: Sender<CtlReply>,
) {
    let mut scratch = Vec::new();
    loop {
        match read_frame(&mut rd, &mut scratch) {
            Ok(Some(Frame::GatherResp(r))) => {
                let tx = pending.lock().unwrap().remove(&r.token);
                if let Some(tx) = tx {
                    let _ = tx.send(r);
                }
            }
            Ok(Some(Frame::StatsResp(s))) => {
                let _ = ctl_tx.send(CtlReply::Stats(s));
            }
            Ok(Some(Frame::MembersResp(m))) => {
                let _ = ctl_tx.send(CtlReply::Members(m));
            }
            Ok(Some(Frame::Ack)) => {
                let _ = ctl_tx.send(CtlReply::Ack);
            }
            // Request kinds arriving at a client, clean EOF, or a decode
            // error all end the connection.
            Ok(Some(_)) | Ok(None) | Err(_) => break,
        }
    }
    // Store-then-clear; see the `closed` field's ordering contract.
    closed.store(true, Ordering::SeqCst);
    pending.lock().unwrap().clear();
}

// ---------------------------------------------------------------------------
// Server side
// ---------------------------------------------------------------------------

/// A partition server listening on a socket: the accept loop, every
/// connection handler, and the underlying worker pool. `join` blocks until
/// a client sends the Shutdown frame (or `stop` is called).
pub struct RemoteServer {
    addr: String,
    stop: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<()>>,
    inbox: Sender<ServerMsg>,
    workers: usize,
}

impl RemoteServer {
    /// The dialable address (real port if bound to port 0).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Wait for the server to be shut down (by a client's Shutdown frame
    /// or [`Self::stop`]).
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }

    /// Local shutdown: stop the pool and the accept loop without waiting
    /// for a client to ask.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for _ in 0..self.workers {
            let _ = self.inbox.send(ServerMsg::Shutdown);
        }
        // Unblock the accept loop with a throwaway connection.
        let _ = Conn::dial(&self.addr);
    }
}

/// Launch one partition server on a socket: `workers` pool threads over
/// the standard shared inbox ([`spawn_pool`] — the same pool the
/// in-process service launches), plus an accept loop that bridges
/// connections onto it. `seed` must equal the in-process service seed for
/// bit-identical sampling (the per-partition stream derivation lives in
/// the pool, not here).
pub fn serve_partition(
    graph: Arc<PartitionGraph>,
    listen: &str,
    seed: u64,
    workers: usize,
) -> Result<RemoteServer> {
    let workers = workers.max(1);
    let listener = Listener::bind(listen)?;
    let addr = listener.local_addr()?;
    let stats = Arc::new(ServerStats::with_workers(workers));
    let (inbox, mut handles) = spawn_pool(graph.clone(), stats.clone(), seed, workers);
    let stop = Arc::new(AtomicBool::new(false));
    let accept = {
        let stop = stop.clone();
        let inbox = inbox.clone();
        let addr = addr.clone();
        std::thread::spawn(move || {
            loop {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let conn = match listener.accept() {
                    Ok(c) => c,
                    Err(_) => {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        continue;
                    }
                };
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                conn.set_low_latency();
                let ctx = ConnCtx {
                    inbox: inbox.clone(),
                    stats: stats.clone(),
                    graph: graph.clone(),
                    workers,
                    stop: stop.clone(),
                    self_addr: addr.clone(),
                };
                // Handlers are detached: they exit when their client
                // disconnects (EOF) or on shutdown; the process/test
                // lifetime is governed by the accept + pool threads.
                std::thread::spawn(move || handle_conn(conn, ctx));
            }
        })
    };
    handles.push(accept);
    Ok(RemoteServer { addr, stop, handles, inbox, workers })
}

struct ConnCtx {
    inbox: Sender<ServerMsg>,
    stats: Arc<ServerStats>,
    graph: Arc<PartitionGraph>,
    workers: usize,
    stop: Arc<AtomicBool>,
    self_addr: String,
}

/// Serialize + write one server→client frame. The write half and its
/// encode scratch live behind one per-connection mutex shared between the
/// reader (control replies) and the writer thread (gather responses).
fn write_frame_locked(wr: &Mutex<(Conn, Vec<u8>)>, f: &Frame) -> bool {
    let mut g = wr.lock().unwrap();
    let (conn, buf) = &mut *g;
    encode_frame(buf, f);
    conn.write_all(buf).is_ok()
}

/// One client connection: decode requests, feed gathers to the pool inbox
/// (tagged with this connection's reply channel), answer control RPCs
/// inline. Responses flow back through a dedicated writer thread so slow
/// clients never block pool workers.
fn handle_conn(conn: Conn, ctx: ConnCtx) {
    let Ok(write_conn) = conn.try_clone() else {
        return;
    };
    let wr = Arc::new(Mutex::new((write_conn, Vec::new())));
    let (resp_tx, resp_rx) = channel::<GatherResponse>();
    let writer = {
        let wr = wr.clone();
        std::thread::spawn(move || {
            while let Ok(resp) = resp_rx.recv() {
                if !write_frame_locked(&wr, &Frame::GatherResp(resp)) {
                    break;
                }
            }
        })
    };
    let mut rd = conn;
    let mut scratch = Vec::new();
    loop {
        match read_frame(&mut rd, &mut scratch) {
            Ok(Some(Frame::Gather(req))) => {
                if ctx.inbox.send(ServerMsg::Gather(req, resp_tx.clone())).is_err() {
                    break; // pool already shut down
                }
            }
            Ok(Some(Frame::Stats)) => {
                let snap = StatsSnapshot::capture(
                    ctx.graph.part_id,
                    &ctx.stats,
                    ctx.graph.nbytes(),
                );
                if !write_frame_locked(&wr, &Frame::StatsResp(snap)) {
                    break;
                }
            }
            Ok(Some(Frame::ResetStats)) => {
                ctx.stats.reset();
                if !write_frame_locked(&wr, &Frame::Ack) {
                    break;
                }
            }
            Ok(Some(Frame::Members)) => {
                let m = MembersInfo {
                    part_id: ctx.graph.part_id as u32,
                    workers: ctx.workers as u32,
                    ids: ctx.graph.global_id.to_vec(),
                };
                if !write_frame_locked(&wr, &Frame::MembersResp(m)) {
                    break;
                }
            }
            Ok(Some(Frame::Shutdown)) => {
                // FIFO inbox: gathers already queued are served before the
                // pool sees these Shutdowns, so an orderly client (which
                // only shuts down after collecting its responses) loses
                // nothing.
                ctx.stop.store(true, Ordering::SeqCst);
                for _ in 0..ctx.workers {
                    let _ = ctx.inbox.send(ServerMsg::Shutdown);
                }
                write_frame_locked(&wr, &Frame::Ack);
                // Unblock the accept loop so it observes the stop flag.
                let _ = Conn::dial(&ctx.self_addr);
                break;
            }
            // Response kinds arriving at a server, clean client
            // disconnect, or garbage all end this connection (the server
            // itself keeps running for other clients unless Shutdown was
            // received).
            Ok(Some(_)) | Ok(None) | Err(_) => break,
        }
    }
    drop(resp_tx);
    let _ = writer.join();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::graph::hetero::build_partitions;
    use crate::partition::{AdaDNE, Partitioner};
    use crate::sampling::request::SampleConfig;
    use crate::util::rng::Rng;

    fn one_partition() -> Arc<PartitionGraph> {
        let mut rng = Rng::new(150);
        let g = generator::chung_lu(400, 4000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 1, 0);
        Arc::new(build_partitions(&g, &ea.part_of_edge, 1).unwrap().remove(0))
    }

    fn gather(seeds: Vec<VId>, salt: u64) -> GatherRequest {
        GatherRequest {
            seeds,
            fanout: 4,
            cfg: SampleConfig::default(),
            salt,
            seed_offset: 0,
            token: 0,
        }
    }

    #[test]
    fn socket_round_trip_matches_channel_transport() {
        let pg = one_partition();
        // Channel reference.
        let stats = Arc::new(ServerStats::with_workers(2));
        let (tx, hs) = spawn_pool(pg.clone(), stats.clone(), 7, 2);
        let chan = ChannelTransport {
            part_id: 0,
            inbox: tx,
            stats,
            graph: pg.clone(),
            workers: 2,
        };
        let (rtx, rrx) = channel();
        chan.send_gather(gather((0..32).map(|i| pg.global(i)).collect(), 0xAB), &rtx)
            .unwrap();
        let want = rrx.recv().unwrap();

        // Socket server on an ephemeral TCP port.
        let srv = serve_partition(pg.clone(), "tcp:127.0.0.1:0", 7, 2).unwrap();
        let sock = SocketTransport::connect(srv.addr()).unwrap();
        assert_eq!(sock.part_id(), 0);
        let (rtx, rrx) = channel();
        sock.send_gather(gather((0..32).map(|i| pg.global(i)).collect(), 0xAB), &rtx)
            .unwrap();
        let got = rrx.recv().unwrap();
        assert_eq!(got.neighbors, want.neighbors, "wire transport changed sampled bits");
        assert_eq!(got.offsets, want.offsets);
        assert_eq!(got.work_edges, want.work_edges);

        // Control RPCs.
        let m = sock.members().unwrap();
        assert_eq!(m.ids, pg.global_id);
        assert_eq!(m.workers, 2);
        let s = sock.stats().unwrap();
        assert_eq!(s.requests, 1);
        assert_eq!(s.graph_bytes as usize, pg.nbytes());
        sock.reset_stats().unwrap();
        assert_eq!(sock.stats().unwrap().requests, 0);

        // Remote shutdown terminates the whole server.
        sock.shutdown().unwrap();
        srv.join();
        chan.shutdown().unwrap();
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn unix_socket_round_trip_and_stale_file_cleanup() {
        let pg = one_partition();
        let path = std::env::temp_dir().join(format!("glisp_t_{}.sock", std::process::id()));
        // Pre-plant a stale socket file; bind must clear it.
        std::fs::write(&path, b"stale").unwrap();
        let addr = format!("unix:{}", path.display());
        let srv = serve_partition(pg.clone(), &addr, 3, 1).unwrap();
        let sock = SocketTransport::connect(srv.addr()).unwrap();
        let (rtx, rrx) = channel();
        sock.send_gather(gather(vec![pg.global(0), pg.global(1)], 5), &rtx).unwrap();
        let resp = rrx.recv().unwrap();
        assert_eq!(resp.offsets.len(), 3);
        sock.shutdown().unwrap();
        srv.join();
        assert!(!path.exists(), "socket file must be cleaned up on shutdown");
    }

    #[test]
    fn concurrent_gathers_demultiplex_by_token() {
        let pg = one_partition();
        let srv = serve_partition(pg.clone(), "tcp:127.0.0.1:0", 11, 4).unwrap();
        let sock = SocketTransport::connect(srv.addr()).unwrap();
        // Fire many gathers with distinct salts before reading anything;
        // each reply channel must get exactly its own response back.
        let mut rxs = Vec::new();
        for salt in 0..24u64 {
            let (rtx, rrx) = channel();
            sock.send_gather(gather((0..8).map(|i| pg.global(i)).collect(), salt), &rtx)
                .unwrap();
            rxs.push((salt, rrx));
        }
        // Ground truth straight from a local pool with the same seed.
        let stats = Arc::new(ServerStats::with_workers(1));
        let (tx, hs) = spawn_pool(pg.clone(), stats.clone(), 11, 1);
        for (salt, rrx) in rxs {
            let got = rrx.recv().expect("response for in-flight gather");
            let (wtx, wrx) = channel();
            tx.send(ServerMsg::Gather(
                gather((0..8).map(|i| pg.global(i)).collect(), salt),
                wtx,
            ))
            .unwrap();
            let want = wrx.recv().unwrap();
            assert_eq!(got.neighbors, want.neighbors, "salt {salt} response misrouted");
        }
        sock.shutdown().unwrap();
        srv.join();
        tx.send(ServerMsg::Shutdown).unwrap();
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn transport_errors_name_peer_address_and_partition() {
        let pg = one_partition();
        let srv = serve_partition(pg.clone(), "tcp:127.0.0.1:0", 5, 1).unwrap();
        let addr = srv.addr().to_string();
        let sock = SocketTransport::connect(&addr).unwrap();
        sock.shutdown().unwrap();
        srv.join();
        // The connection is gone; every operation must say WHERE it died.
        let err = sock.stats().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(&addr), "error must name the peer address: {msg}");
        assert!(msg.contains("partition 0"), "error must name the partition: {msg}");
        // The reader thread is provably gone (stats() above failed on its
        // dropped control channel), so a gather must fail fast — either at
        // write time (broken pipe) or at the closed-connection check that
        // covers OS-buffered writes — and the error must name the peer.
        let (rtx, rrx) = channel();
        match sock.send_gather(gather(vec![pg.global(0)], 1), &rtx) {
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains(&addr), "gather error must name the peer: {msg}");
                assert!(msg.contains("partition 0"), "gather error must name partition: {msg}");
            }
            Ok(()) => {
                // Belt and braces: even if a send slipped through, the dead
                // reader must already have dropped every pending sender.
                drop(rtx);
                assert!(rrx.recv().is_err(), "no response may arrive post-shutdown");
            }
        }
        // Dialing a dead address names it too.
        let err = SocketTransport::connect(&addr).unwrap_err();
        assert!(format!("{err:#}").contains(&addr), "{err:#}");
    }
}
