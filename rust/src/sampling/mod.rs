//! Graph sampling service (paper §III-C): Gather-Apply K-hop neighbor
//! sampling over per-partition servers, with Vitter Algorithm D uniform
//! sampling, Efraimidis–Spirakis A-ES weighted sampling, and the
//! DistDGL-like single-owner baseline. The protocol is transport-neutral
//! (DESIGN.md §12): `wire` is the binary frame codec, `transport` carries
//! it over in-process channels or TCP/Unix sockets, and the service/client
//! layers above cannot tell the deployments apart (bit-identical samples).

pub mod aes;
pub mod algo_d;
pub mod baseline;
pub mod client;
pub mod request;
pub mod server;
pub mod service;
pub mod subgraph;
pub mod transport;
pub mod wire;

pub use client::{ClientScratch, OneHopSample, RouteMode, SamplingClient};
pub use request::{Direction, GatherOp, GatherRequest, GatherResponse, SampleConfig, PAD};
pub use service::{balanced_seeds, SamplingService, ServiceConfig};
pub use subgraph::{sample_tree, TreeSample};
pub use transport::{
    serve_partition, ChannelTransport, RemoteServer, SocketTransport, Transport,
};
pub use wire::{MembersInfo, StatsSnapshot, WIRE_VERSION};
