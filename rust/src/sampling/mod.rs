//! Graph sampling service (paper §III-C): Gather-Apply K-hop neighbor
//! sampling over per-partition servers, with Vitter Algorithm D uniform
//! sampling, Efraimidis–Spirakis A-ES weighted sampling, and the
//! DistDGL-like single-owner baseline.

pub mod aes;
pub mod algo_d;
pub mod baseline;
pub mod client;
pub mod request;
pub mod server;
pub mod service;
pub mod subgraph;

pub use client::{OneHopSample, RouteMode, SamplingClient};
pub use request::{Direction, GatherRequest, GatherResponse, SampleConfig, PAD};
pub use service::{balanced_seeds, SamplingService, ServiceConfig};
pub use subgraph::{sample_tree, TreeSample};
