//! Length-prefixed binary wire codec for the sampling protocol — the
//! serialization half of running partition servers as separate processes
//! (DESIGN.md §12). Transport-agnostic: the same frames flow over TCP and
//! Unix sockets ([`crate::sampling::transport`]).
//!
//! # Frame layout (all integers little-endian)
//!
//! ```text
//! u32 len | u8 version | u8 kind | body
//! ```
//!
//! `len` counts every byte after the prefix (version + kind + body).
//! Decoding follows the `harness::bench::from_json` drift-gate philosophy:
//! strict, not lenient — a version byte other than [`WIRE_VERSION`], an
//! unknown kind, a truncated body, or trailing bytes after the body are
//! all hard errors, so a peer built from a different protocol revision
//! fails loudly at the first frame instead of desynchronizing silently.
//! Any layout change (field added, widened, reordered) must bump
//! [`WIRE_VERSION`]; there is deliberately no "ignore what you don't
//! know" path.

use anyhow::{bail, Context, Result};
use std::io::Read;

use crate::graph::csr::VId;
use crate::sampling::request::{Direction, GatherOp, GatherRequest, GatherResponse, SampleConfig};
use crate::sampling::server::ServerStats;

/// Bump on ANY layout change; both sides reject a mismatch.
/// v2: Gather carries a one-byte operator tag ([`GatherOp`]) between the
/// weighted byte and the etype pair.
pub const WIRE_VERSION: u8 = 2;

/// Upper bound on `len` accepted by [`read_frame`] — a corrupt or hostile
/// length prefix must not drive a multi-gigabyte allocation.
pub const MAX_FRAME: usize = 256 << 20;

/// Point-in-time copy of one partition server's [`ServerStats`] counters
/// (plus its graph footprint), shippable across the wire. This is how
/// `SamplingService::workload()`/`busy_secs()` work identically for
/// in-process and remote servers.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsSnapshot {
    pub part_id: u32,
    pub requests: u64,
    pub seeds: u64,
    pub edges_scanned: u64,
    pub neighbors_returned: u64,
    pub busy_ns: u64,
    /// Bytes of the server's compact partition structure (Table III).
    pub graph_bytes: u64,
    pub worker_requests: Vec<u64>,
    pub worker_busy_ns: Vec<u64>,
}

impl StatsSnapshot {
    /// Snapshot shared atomics (Relaxed — same ordering the in-process
    /// readers use).
    pub fn capture(part_id: usize, stats: &ServerStats, graph_bytes: usize) -> Self {
        use std::sync::atomic::Ordering::Relaxed;
        Self {
            part_id: part_id as u32,
            requests: stats.requests.load(Relaxed),
            seeds: stats.seeds.load(Relaxed),
            edges_scanned: stats.edges_scanned.load(Relaxed),
            neighbors_returned: stats.neighbors_returned.load(Relaxed),
            busy_ns: stats.busy_ns.load(Relaxed),
            graph_bytes: graph_bytes as u64,
            worker_requests: stats.worker_requests.iter().map(|w| w.load(Relaxed)).collect(),
            worker_busy_ns: stats.worker_busy_ns.iter().map(|w| w.load(Relaxed)).collect(),
        }
    }
}

/// A partition server's identity card, fetched once per connection: which
/// partition it serves, its pool size, and the sorted global vertex ids it
/// replicates (what `SamplingService::connect` builds the membership
/// matrix and `balanced_seeds` draws from).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MembersInfo {
    pub part_id: u32,
    pub workers: u32,
    pub ids: Vec<VId>,
}

/// Every message of the sampling protocol. Gather/GatherResp carry a
/// client-assigned `token` so concurrent gathers can share one connection;
/// the control messages (Stats/Members/ResetStats/Shutdown) are simple
/// one-at-a-time request/reply pairs.
#[derive(Clone, Debug)]
pub enum Frame {
    Gather(GatherRequest),
    GatherResp(GatherResponse),
    Stats,
    StatsResp(StatsSnapshot),
    ResetStats,
    /// Generic control acknowledgement (ResetStats, Shutdown).
    Ack,
    Members,
    MembersResp(MembersInfo),
    Shutdown,
}

// Frame kind bytes. Never reuse a retired value within a version.
const K_GATHER: u8 = 1;
const K_GATHER_RESP: u8 = 2;
const K_STATS: u8 = 3;
const K_STATS_RESP: u8 = 4;
const K_RESET_STATS: u8 = 5;
const K_ACK: u8 = 6;
const K_MEMBERS: u8 = 7;
const K_MEMBERS_RESP: u8 = 8;
const K_SHUTDOWN: u8 = 9;

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32s(buf: &mut Vec<u8>, xs: &[u32]) {
    put_u32(buf, xs.len() as u32);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u64s(buf: &mut Vec<u8>, xs: &[u64]) {
    put_u32(buf, xs.len() as u32);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f64s(buf: &mut Vec<u8>, xs: &[f64]) {
    put_u32(buf, xs.len() as u32);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Encode one frame into `buf` (cleared first — callers keep one scratch
/// per connection, so steady-state encoding allocates nothing).
pub fn encode_frame(buf: &mut Vec<u8>, f: &Frame) {
    buf.clear();
    buf.extend_from_slice(&[0, 0, 0, 0]); // length prefix back-patched below
    buf.push(WIRE_VERSION);
    match f {
        Frame::Gather(r) => {
            buf.push(K_GATHER);
            put_u64(buf, r.token);
            put_u64(buf, r.salt);
            put_u64(buf, r.fanout as u64);
            put_u32(buf, r.seed_offset);
            buf.push(match r.cfg.direction {
                Direction::Out => 0,
                Direction::In => 1,
            });
            buf.push(r.cfg.weighted as u8);
            buf.push(match r.cfg.op {
                GatherOp::Auto => 0,
                GatherOp::TopK => 1,
                GatherOp::InDegree => 2,
            });
            match r.cfg.etype {
                None => buf.extend_from_slice(&[0, 0]),
                Some(t) => buf.extend_from_slice(&[1, t]),
            }
            put_u32s(buf, &r.seeds);
        }
        Frame::GatherResp(r) => {
            buf.push(K_GATHER_RESP);
            put_u64(buf, r.token);
            put_u32(buf, r.part_id as u32);
            put_u32(buf, r.seed_offset);
            put_u64(buf, r.work_edges);
            put_u32s(buf, &r.offsets);
            put_u32s(buf, &r.neighbors);
            put_f64s(buf, &r.scores);
        }
        Frame::Stats => buf.push(K_STATS),
        Frame::StatsResp(s) => {
            buf.push(K_STATS_RESP);
            put_u32(buf, s.part_id);
            put_u64(buf, s.requests);
            put_u64(buf, s.seeds);
            put_u64(buf, s.edges_scanned);
            put_u64(buf, s.neighbors_returned);
            put_u64(buf, s.busy_ns);
            put_u64(buf, s.graph_bytes);
            put_u64s(buf, &s.worker_requests);
            put_u64s(buf, &s.worker_busy_ns);
        }
        Frame::ResetStats => buf.push(K_RESET_STATS),
        Frame::Ack => buf.push(K_ACK),
        Frame::Members => buf.push(K_MEMBERS),
        Frame::MembersResp(m) => {
            buf.push(K_MEMBERS_RESP);
            put_u32(buf, m.part_id);
            put_u32(buf, m.workers);
            put_u32s(buf, &m.ids);
        }
        Frame::Shutdown => buf.push(K_SHUTDOWN),
    }
    let len = (buf.len() - 4) as u32;
    buf[..4].copy_from_slice(&len.to_le_bytes());
}

/// Strict cursor over a frame payload: every read is bounds-checked, and
/// [`Cursor::finish`] rejects trailing bytes.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "truncated frame: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 8)?;
        Ok(raw.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 8)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes after frame body", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

/// Decode one frame payload (the bytes after the u32 length prefix).
/// Strict: version/kind/length mismatches and trailing bytes are errors.
pub fn decode_frame(payload: &[u8]) -> Result<Frame> {
    let mut c = Cursor { buf: payload, pos: 0 };
    let v = c.u8().context("frame shorter than the version byte")?;
    if v != WIRE_VERSION {
        bail!("wire version {v} != supported {WIRE_VERSION} (rebuild both sides)");
    }
    let kind = c.u8().context("frame shorter than the kind byte")?;
    let frame = match kind {
        K_GATHER => {
            let token = c.u64()?;
            let salt = c.u64()?;
            let fanout = c.u64()? as usize;
            let seed_offset = c.u32()?;
            let direction = match c.u8()? {
                0 => Direction::Out,
                1 => Direction::In,
                d => bail!("bad direction byte {d}"),
            };
            let weighted = match c.u8()? {
                0 => false,
                1 => true,
                w => bail!("bad weighted byte {w}"),
            };
            let op = match c.u8()? {
                0 => GatherOp::Auto,
                1 => GatherOp::TopK,
                2 => GatherOp::InDegree,
                b => bail!("bad op byte {b}"),
            };
            let etype = match (c.u8()?, c.u8()?) {
                (0, 0) => None,
                (1, t) => Some(t),
                (tag, _) => bail!("bad etype tag {tag}"),
            };
            Frame::Gather(GatherRequest {
                seeds: c.u32s()?,
                fanout,
                cfg: SampleConfig { direction, weighted, etype, op },
                salt,
                seed_offset,
                token,
            })
        }
        K_GATHER_RESP => {
            let token = c.u64()?;
            let part_id = c.u32()? as usize;
            let seed_offset = c.u32()?;
            let work_edges = c.u64()?;
            Frame::GatherResp(GatherResponse {
                part_id,
                seed_offset,
                offsets: c.u32s()?,
                neighbors: c.u32s()?,
                scores: c.f64s()?,
                work_edges,
                token,
            })
        }
        K_STATS => Frame::Stats,
        K_STATS_RESP => Frame::StatsResp(StatsSnapshot {
            part_id: c.u32()?,
            requests: c.u64()?,
            seeds: c.u64()?,
            edges_scanned: c.u64()?,
            neighbors_returned: c.u64()?,
            busy_ns: c.u64()?,
            graph_bytes: c.u64()?,
            worker_requests: c.u64s()?,
            worker_busy_ns: c.u64s()?,
        }),
        K_RESET_STATS => Frame::ResetStats,
        K_ACK => Frame::Ack,
        K_MEMBERS => Frame::Members,
        K_MEMBERS_RESP => Frame::MembersResp(MembersInfo {
            part_id: c.u32()?,
            workers: c.u32()?,
            ids: c.u32s()?,
        }),
        K_SHUTDOWN => Frame::Shutdown,
        k => bail!("unknown frame kind {k}"),
    };
    c.finish()?;
    Ok(frame)
}

/// Read one frame off a blocking stream into the reusable `scratch`
/// buffer. `Ok(None)` = clean EOF at a frame boundary (the peer closed the
/// connection); EOF mid-frame is an error.
pub fn read_frame<R: Read>(r: &mut R, scratch: &mut Vec<u8>) -> Result<Option<Frame>> {
    let mut prefix = [0u8; 4];
    // A clean close lands exactly between frames: zero bytes of the next
    // length prefix.
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut prefix[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => bail!("connection closed mid length-prefix ({got}/4 bytes)"),
            Ok(k) => got += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading frame length"),
        }
    }
    let len = u32::from_le_bytes(prefix) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds cap {MAX_FRAME} (corrupt stream?)");
    }
    scratch.clear();
    scratch.resize(len, 0);
    r.read_exact(scratch).with_context(|| format!("reading {len}-byte frame body"))?;
    decode_frame(scratch)
        .map(Some)
        .with_context(|| format!("decoding {len}-byte frame"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::prop_check;
    use crate::util::rng::Rng;
    use crate::{prop_assert, prop_assert_eq};

    fn round_trip(f: &Frame) -> Frame {
        let mut buf = Vec::new();
        encode_frame(&mut buf, f);
        let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        assert_eq!(len, buf.len() - 4, "length prefix must cover the payload");
        decode_frame(&buf[4..]).expect("round trip decode")
    }

    fn arb_cfg(rng: &mut Rng) -> SampleConfig {
        SampleConfig {
            direction: if rng.usize(2) == 0 { Direction::Out } else { Direction::In },
            weighted: rng.usize(2) == 1,
            etype: match rng.usize(3) {
                0 => None,
                _ => Some(rng.usize(256) as u8),
            },
            op: [GatherOp::Auto, GatherOp::TopK, GatherOp::InDegree][rng.usize(3)],
        }
    }

    #[test]
    fn gather_request_round_trips() {
        prop_check("gather request round trip", 120, |rng| {
            // Empty seed lists and usize::MAX fanout are legal frames.
            let n = [0, 1, 7, 300][rng.usize(4)];
            let req = GatherRequest {
                seeds: (0..n).map(|_| rng.next_u64() as VId).collect(),
                fanout: if rng.usize(5) == 0 { usize::MAX } else { rng.usize(1 << 20) },
                cfg: arb_cfg(rng),
                salt: rng.next_u64(),
                seed_offset: rng.next_u64() as u32,
                token: rng.next_u64(),
            };
            let Frame::Gather(got) = round_trip(&Frame::Gather(req.clone())) else {
                return Err("kind changed in flight".into());
            };
            prop_assert_eq!(got.seeds, req.seeds);
            prop_assert_eq!(got.fanout, req.fanout);
            prop_assert_eq!(got.salt, req.salt);
            prop_assert_eq!(got.seed_offset, req.seed_offset);
            prop_assert_eq!(got.token, req.token);
            prop_assert_eq!(got.cfg.weighted, req.cfg.weighted);
            prop_assert_eq!(got.cfg.etype, req.cfg.etype);
            prop_assert_eq!(got.cfg.op, req.cfg.op);
            prop_assert!(got.cfg.direction == req.cfg.direction, "direction drifted");
            Ok(())
        });
    }

    #[test]
    fn gather_response_round_trips() {
        prop_check("gather response round trip", 120, |rng| {
            let seeds = rng.usize(40);
            let mut offsets = vec![0u32];
            for _ in 0..seeds {
                offsets.push(offsets.last().unwrap() + rng.usize(6) as u32);
            }
            let total = *offsets.last().unwrap() as usize;
            let weighted = rng.usize(2) == 1;
            let resp = GatherResponse {
                part_id: rng.usize(1 << 16),
                seed_offset: rng.next_u64() as u32,
                offsets,
                neighbors: (0..total).map(|_| rng.next_u64() as VId).collect(),
                scores: if weighted { (0..total).map(|_| rng.f64()).collect() } else { vec![] },
                work_edges: rng.next_u64(),
                token: rng.next_u64(),
            };
            let Frame::GatherResp(got) = round_trip(&Frame::GatherResp(resp.clone())) else {
                return Err("kind changed in flight".into());
            };
            prop_assert_eq!(got.part_id, resp.part_id);
            prop_assert_eq!(got.seed_offset, resp.seed_offset);
            prop_assert_eq!(got.offsets, resp.offsets);
            prop_assert_eq!(got.neighbors, resp.neighbors);
            prop_assert_eq!(got.work_edges, resp.work_edges);
            prop_assert_eq!(got.token, resp.token);
            // Scores carry exact f64 bits (A-ES merge order depends on them).
            prop_assert_eq!(
                got.scores.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
                resp.scores.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
            );
            Ok(())
        });
    }

    #[test]
    fn stats_and_members_round_trip() {
        prop_check("stats/members round trip", 80, |rng| {
            let workers = rng.usize(6);
            let snap = StatsSnapshot {
                part_id: rng.next_u64() as u32,
                requests: rng.next_u64(),
                seeds: rng.next_u64(),
                edges_scanned: rng.next_u64(),
                neighbors_returned: rng.next_u64(),
                busy_ns: rng.next_u64(),
                graph_bytes: rng.next_u64(),
                worker_requests: (0..workers).map(|_| rng.next_u64()).collect(),
                worker_busy_ns: (0..workers).map(|_| rng.next_u64()).collect(),
            };
            let Frame::StatsResp(got) = round_trip(&Frame::StatsResp(snap.clone())) else {
                return Err("kind changed in flight".into());
            };
            prop_assert_eq!(got, snap);
            let m = MembersInfo {
                part_id: rng.next_u64() as u32,
                workers: rng.next_u64() as u32,
                ids: (0..rng.usize(200)).map(|_| rng.next_u64() as VId).collect(),
            };
            let Frame::MembersResp(got) = round_trip(&Frame::MembersResp(m.clone())) else {
                return Err("kind changed in flight".into());
            };
            prop_assert_eq!(got, m);
            Ok(())
        });
    }

    #[test]
    fn control_frames_round_trip() {
        for f in [Frame::Stats, Frame::ResetStats, Frame::Ack, Frame::Members, Frame::Shutdown] {
            let got = round_trip(&f);
            assert_eq!(std::mem::discriminant(&got), std::mem::discriminant(&f));
        }
    }

    #[test]
    fn strict_decode_rejects_bad_version() {
        let mut buf = Vec::new();
        encode_frame(&mut buf, &Frame::Stats);
        buf[4] = WIRE_VERSION + 1;
        let err = decode_frame(&buf[4..]).unwrap_err();
        assert!(format!("{err:#}").contains("wire version"), "{err:#}");
    }

    #[test]
    fn strict_decode_rejects_unknown_kind() {
        let err = decode_frame(&[WIRE_VERSION, 200]).unwrap_err();
        assert!(format!("{err:#}").contains("unknown frame kind"), "{err:#}");
    }

    #[test]
    fn strict_decode_rejects_truncation_at_every_length() {
        // Truncating a real Gather frame at ANY interior byte must fail —
        // there is no prefix of the body that parses as a shorter frame.
        let req = GatherRequest {
            seeds: vec![5, 6, 7],
            fanout: 4,
            cfg: SampleConfig { weighted: true, ..Default::default() },
            salt: 99,
            seed_offset: 3,
            token: 12,
        };
        let mut buf = Vec::new();
        encode_frame(&mut buf, &Frame::Gather(req));
        let payload = &buf[4..];
        for cut in 0..payload.len() {
            assert!(
                decode_frame(&payload[..cut]).is_err(),
                "truncation at {cut}/{} must not parse",
                payload.len()
            );
        }
    }

    #[test]
    fn strict_decode_rejects_trailing_garbage() {
        let mut buf = Vec::new();
        encode_frame(&mut buf, &Frame::Members);
        let mut payload = buf[4..].to_vec();
        payload.push(0xAB);
        let err = decode_frame(&payload).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
    }

    #[test]
    fn read_frame_handles_streams_and_eof() {
        use std::io::Cursor;
        let mut buf = Vec::new();
        let mut stream = Vec::new();
        encode_frame(&mut buf, &Frame::Stats);
        stream.extend_from_slice(&buf);
        encode_frame(&mut buf, &Frame::Ack); // scratch reuse: same buf
        stream.extend_from_slice(&buf);
        let mut rd = Cursor::new(stream.clone());
        let mut scratch = Vec::new();
        assert!(matches!(read_frame(&mut rd, &mut scratch), Ok(Some(Frame::Stats))));
        assert!(matches!(read_frame(&mut rd, &mut scratch), Ok(Some(Frame::Ack))));
        // Clean EOF at a frame boundary.
        assert!(matches!(read_frame(&mut rd, &mut scratch), Ok(None)));
        // EOF mid-frame is an error, not a silent None.
        let mut rd = Cursor::new(stream[..stream.len() - 2].to_vec());
        assert!(matches!(read_frame(&mut rd, &mut scratch), Ok(Some(Frame::Stats))));
        assert!(read_frame(&mut rd, &mut scratch).is_err());
        // An absurd length prefix is rejected before allocating.
        let mut rd = Cursor::new(u32::MAX.to_le_bytes().to_vec());
        let err = read_frame(&mut rd, &mut scratch).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds cap"), "{err:#}");
    }
}
