//! Vitter's Algorithm D (TOMS'87) — sequential uniform sampling of k
//! records from n without replacement in O(k) expected time, used by the
//! UniformGatherOp (paper Algorithm 2, line 5).
//!
//! The implementation follows Vitter's Method D: draw the skip distance S
//! (number of records to jump over before the next selected one) from its
//! exact distribution by rejection, with the cheap Method A fallback when
//! k is a large fraction of n (Vitter's own crossover rule).

use crate::util::rng::Rng;

/// Sample k distinct indices from [0, n), returned in increasing order.
pub fn sample(rng: &mut Rng, n: usize, k: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(k);
    sample_into(rng, n, k, &mut out);
    out
}

/// [`sample`] into a caller-owned buffer (cleared first) — identical draws,
/// no allocation when the buffer's capacity already fits k. The gather
/// arena (DESIGN.md §14) reuses one buffer across every uniform gather a
/// pool worker serves.
pub fn sample_into(rng: &mut Rng, n: usize, k: usize, out: &mut Vec<usize>) {
    assert!(k <= n, "k={k} > n={n}");
    out.clear();
    if k == 0 {
        return;
    }
    if k == n {
        out.extend(0..n);
        return;
    }
    // Vitter's crossover: Method D pays off when n/k is large.
    const ALPHA_INV: usize = 13;
    if n >= ALPHA_INV * k {
        method_d(rng, n, k, out)
    } else {
        method_a(rng, n, k, out)
    }
}

/// Method A: scan records, selecting each with the exact conditional
/// probability k_remaining / n_remaining. O(n), tiny constant.
fn method_a(rng: &mut Rng, n: usize, k: usize, out: &mut Vec<usize>) {
    let mut need = k;
    let mut remaining = n;
    let mut idx = 0usize;
    while need > 0 {
        if rng.f64() * (remaining as f64) < need as f64 {
            out.push(idx);
            need -= 1;
        }
        idx += 1;
        remaining -= 1;
    }
}

/// Method D: generate skips S via rejection from the exact skip
/// distribution. Expected O(k) time independent of n. Direct transcription
/// of Vitter's Program D (TOMS'87, §6).
fn method_d(rng: &mut Rng, n: usize, k: usize, out: &mut Vec<usize>) {
    let mut cur = 0usize; // absolute index of the next candidate record
    let mut nn = n as f64; // N: records remaining
    let mut kk = k as f64; // n: samples remaining
    let mut vprime = rng.f64_open().powf(1.0 / kk);
    let mut qu1 = nn - kk + 1.0;

    while kk > 1.0 {
        let kmin1inv = 1.0 / (kk - 1.0);
        let s: f64;
        loop {
            // Step D2: X from the majorizing density g via vprime.
            let mut x;
            loop {
                x = nn * (1.0 - vprime);
                if x < qu1 {
                    break;
                }
                vprime = rng.f64_open().powf(1.0 / kk);
            }
            let s_cand = x.floor();
            // Step D3: squeeze acceptance test.
            let u = rng.f64_open();
            let y1 = (u * nn / qu1).powf(kmin1inv);
            vprime = y1 * (1.0 - x / nn) * (qu1 / (qu1 - s_cand));
            if vprime <= 1.0 {
                s = s_cand;
                break;
            }
            // Step D4: exact f/cg test.
            let mut y2 = 1.0;
            let mut top = nn - 1.0;
            let (mut bottom, limit) = if kk - 1.0 > s_cand {
                (nn - kk, nn - s_cand)
            } else {
                (nn - s_cand - 1.0, qu1)
            };
            let mut t = nn - 1.0;
            while t >= limit {
                y2 *= top / bottom;
                top -= 1.0;
                bottom -= 1.0;
                t -= 1.0;
            }
            if nn / (nn - x) >= y1 * y2.powf(kmin1inv) {
                vprime = rng.f64_open().powf(kmin1inv);
                s = s_cand;
                break;
            }
            vprime = rng.f64_open().powf(1.0 / kk);
        }
        // Skip S records, select the next one.
        out.push(cur + s as usize);
        cur += s as usize + 1;
        nn -= s + 1.0;
        kk -= 1.0;
        qu1 -= s;
    }
    // kk == 1: the last record is uniform over the remainder.
    let s = (nn * vprime).floor().min(nn - 1.0).max(0.0) as usize;
    out.push(cur + s);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_valid(s: &[usize], n: usize, k: usize) {
        assert_eq!(s.len(), k);
        for w in s.windows(2) {
            assert!(w[0] < w[1], "not strictly increasing: {s:?}");
        }
        assert!(s.iter().all(|&i| i < n));
    }

    #[test]
    fn basic_validity_both_methods() {
        let mut rng = Rng::new(100);
        for &(n, k) in &[(10, 3), (100, 99), (1000, 5), (100_000, 7), (50, 50), (7, 0)] {
            for _ in 0..20 {
                let s = sample(&mut rng, n, k);
                check_valid(&s, n, k);
            }
        }
    }

    #[test]
    fn uniform_marginals() {
        // Each index should appear with probability k/n.
        let (n, k, trials) = (40usize, 8usize, 30_000usize);
        let mut rng = Rng::new(101);
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            for i in sample(&mut rng, n, k) {
                counts[i] += 1;
            }
        }
        let expected = trials as f64 * k as f64 / n as f64;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expected).abs() < expected * 0.08,
                "index {i}: {c} vs {expected}"
            );
        }
    }

    #[test]
    fn method_d_uniform_marginals_large_n() {
        // Force the Method D path (n >= 13k) and verify marginals.
        let (n, k, trials) = (2600usize, 4usize, 40_000usize);
        let mut rng = Rng::new(102);
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            for i in sample(&mut rng, n, k) {
                counts[i] += 1;
            }
        }
        let expected = trials as f64 * k as f64 / n as f64;
        // Aggregate into 13 buckets of 200 to reduce variance.
        for chunk in counts.chunks(200) {
            let s: usize = chunk.iter().sum();
            let e = expected * 200.0;
            assert!((s as f64 - e).abs() < e * 0.07, "bucket {s} vs {e}");
        }
    }

    #[test]
    fn sample_into_reuse_matches_fresh() {
        let mut buf = Vec::new();
        for seed in 0..5u64 {
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            for &(n, k) in &[(10usize, 3usize), (1000, 5), (100_000, 7), (50, 50), (7, 0)] {
                sample_into(&mut a, n, k, &mut buf);
                assert_eq!(buf, sample(&mut b, n, k), "n={n} k={k}");
            }
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let a = sample(&mut Rng::new(7), 10_000, 12);
        let b = sample(&mut Rng::new(7), 10_000, 12);
        assert_eq!(a, b);
    }
}
