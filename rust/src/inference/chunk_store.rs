//! Chunked embedding store — the simulated-DFS substrate (paper: Zarr
//! chunks on HDFS). Embedding matrices are chunked by cache-local vertex
//! rank into `[chunk_size, dim]` f32 files; reads are tagged with a
//! *virtual cost* (remote ≫ local-disk ≫ memory) so the Fig. 14 cache
//! speedups are measured as cost ratios instead of sleeping on fake
//! network latency (DESIGN.md §3).
//!
//! Stats are atomic so one store can be read concurrently by the
//! engine's per-partition worker threads (each behind its own
//! `CacheSystem`, which hands chunks out as shared `Arc` allocations);
//! writes happen only between layer slices, on the engine's barrier.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

/// Relative virtual costs of one chunk read at each tier. The 100:10:1
/// ratio approximates HDFS-read : local-SSD-read : memcpy for the paper's
/// 32768×128 chunks.
pub const COST_REMOTE: u64 = 100;
pub const COST_STATIC: u64 = 10;
pub const COST_DYNAMIC: u64 = 1;

#[derive(Debug, Default)]
pub struct StoreStats {
    pub remote_reads: AtomicU64,
    pub static_reads: AtomicU64,
    pub dynamic_hits: AtomicU64,
    pub writes: AtomicU64,
    pub virtual_cost: AtomicU64,
}

impl StoreStats {
    pub fn chunk_reads(&self) -> u64 {
        self.remote_reads.load(Ordering::Relaxed) + self.static_reads.load(Ordering::Relaxed)
    }

    pub fn total_cost(&self) -> u64 {
        self.virtual_cost.load(Ordering::Relaxed)
    }

    pub fn hit_ratio(&self) -> f64 {
        let hits = self.dynamic_hits.load(Ordering::Relaxed) as f64;
        let total = hits + self.chunk_reads() as f64;
        if total == 0.0 {
            0.0
        } else {
            hits / total
        }
    }
}

/// One layer's embedding matrix, chunked on "DFS" (a local directory).
pub struct ChunkStore {
    dir: PathBuf,
    pub chunk_size: usize,
    pub dim: usize,
    pub num_chunks: usize,
    pub stats: StoreStats,
}

impl ChunkStore {
    pub fn create(dir: PathBuf, n_rows: usize, chunk_size: usize, dim: usize) -> Result<Self> {
        std::fs::create_dir_all(&dir)?;
        Ok(Self {
            dir,
            chunk_size,
            dim,
            num_chunks: n_rows.div_ceil(chunk_size),
            stats: StoreStats::default(),
        })
    }

    pub fn chunk_of_row(&self, row: usize) -> usize {
        row / self.chunk_size
    }

    fn path(&self, chunk: usize) -> PathBuf {
        self.dir.join(format!("chunk_{chunk:06}.bin"))
    }

    /// Write one chunk ([chunk_size, dim] row-major; short final chunk ok).
    pub fn write_chunk(&self, chunk: usize, data: &[f32]) -> Result<()> {
        assert!(data.len() <= self.chunk_size * self.dim);
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        std::fs::write(self.path(chunk), bytes)?;
        self.stats.writes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Raw chunk read, tagged with the tier it was served from.
    pub fn read_chunk(&self, chunk: usize, tier: Tier) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.path(chunk))
            .with_context(|| format!("chunk {chunk} missing"))?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        match tier {
            Tier::Remote => {
                self.stats.remote_reads.fetch_add(1, Ordering::Relaxed);
                self.stats.virtual_cost.fetch_add(COST_REMOTE, Ordering::Relaxed);
            }
            Tier::Static => {
                self.stats.static_reads.fetch_add(1, Ordering::Relaxed);
                self.stats.virtual_cost.fetch_add(COST_STATIC, Ordering::Relaxed);
            }
        }
        Ok(data)
    }

    pub fn note_dynamic_hit(&self) {
        self.stats.dynamic_hits.fetch_add(1, Ordering::Relaxed);
        self.stats.virtual_cost.fetch_add(COST_DYNAMIC, Ordering::Relaxed);
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Remote,
    Static,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("glisp_cs_{name}"));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn write_read_round_trip() {
        let cs = ChunkStore::create(tmp("rt"), 100, 16, 4).unwrap();
        assert_eq!(cs.num_chunks, 7);
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        cs.write_chunk(2, &data).unwrap();
        let back = cs.read_chunk(2, Tier::Static).unwrap();
        assert_eq!(back, data);
        assert_eq!(cs.stats.static_reads.load(Ordering::Relaxed), 1);
        assert_eq!(cs.stats.total_cost(), COST_STATIC);
    }

    #[test]
    fn cost_accounting_by_tier() {
        let cs = ChunkStore::create(tmp("cost"), 32, 16, 2).unwrap();
        cs.write_chunk(0, &[0.0; 32]).unwrap();
        cs.read_chunk(0, Tier::Remote).unwrap();
        cs.read_chunk(0, Tier::Static).unwrap();
        cs.note_dynamic_hit();
        assert_eq!(cs.stats.total_cost(), COST_REMOTE + COST_STATIC + COST_DYNAMIC);
        assert_eq!(cs.stats.chunk_reads(), 2);
        assert!((cs.stats.hit_ratio() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn missing_chunk_errors() {
        let cs = ChunkStore::create(tmp("miss"), 32, 16, 2).unwrap();
        assert!(cs.read_chunk(1, Tier::Remote).is_err());
    }
}
