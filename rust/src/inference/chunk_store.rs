//! Chunked embedding store — the simulated-DFS substrate (paper: Zarr
//! chunks on HDFS). Embedding matrices are chunked by cache-local vertex
//! rank into `[chunk_size, dim]` f32 files; reads are tagged with a
//! *virtual cost* (remote ≫ local-disk ≫ memory) so the Fig. 14 cache
//! speedups are measured as cost ratios instead of sleeping on fake
//! network latency (DESIGN.md §3).
//!
//! Stats are atomic so one store can be read concurrently by the
//! engine's per-partition worker threads (each behind its own
//! `CacheSystem`, which hands chunks out as shared `Arc` allocations);
//! writes happen only between layer slices, on the engine's barrier.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

/// Relative virtual costs of one chunk read at each tier. The 100:10:1
/// ratio approximates HDFS-read : local-SSD-read : memcpy for the paper's
/// 32768×128 chunks.
pub const COST_REMOTE: u64 = 100;
pub const COST_STATIC: u64 = 10;
pub const COST_DYNAMIC: u64 = 1;

#[derive(Debug, Default)]
pub struct StoreStats {
    pub remote_reads: AtomicU64,
    pub static_reads: AtomicU64,
    pub dynamic_hits: AtomicU64,
    pub writes: AtomicU64,
    pub virtual_cost: AtomicU64,
}

impl StoreStats {
    pub fn chunk_reads(&self) -> u64 {
        self.remote_reads.load(Ordering::Relaxed) + self.static_reads.load(Ordering::Relaxed)
    }

    pub fn total_cost(&self) -> u64 {
        self.virtual_cost.load(Ordering::Relaxed)
    }

    pub fn hit_ratio(&self) -> f64 {
        let hits = self.dynamic_hits.load(Ordering::Relaxed) as f64;
        let total = hits + self.chunk_reads() as f64;
        if total == 0.0 {
            0.0
        } else {
            hits / total
        }
    }
}

/// One layer's embedding matrix, chunked on "DFS" (a local directory).
pub struct ChunkStore {
    dir: PathBuf,
    pub chunk_size: usize,
    pub dim: usize,
    pub n_rows: usize,
    pub num_chunks: usize,
    pub stats: StoreStats,
}

impl ChunkStore {
    pub fn create(dir: PathBuf, n_rows: usize, chunk_size: usize, dim: usize) -> Result<Self> {
        std::fs::create_dir_all(&dir)?;
        Ok(Self {
            dir,
            chunk_size,
            dim,
            n_rows,
            num_chunks: n_rows.div_ceil(chunk_size),
            stats: StoreStats::default(),
        })
    }

    /// Rows held by `chunk` (the final chunk may be short).
    pub fn rows_in_chunk(&self, chunk: usize) -> usize {
        debug_assert!(chunk < self.num_chunks);
        (self.n_rows - chunk * self.chunk_size).min(self.chunk_size)
    }

    pub fn chunk_of_row(&self, row: usize) -> usize {
        row / self.chunk_size
    }

    fn path(&self, chunk: usize) -> PathBuf {
        self.dir.join(format!("chunk_{chunk:06}.bin"))
    }

    /// Write one chunk ([chunk_size, dim] row-major; short final chunk ok).
    pub fn write_chunk(&self, chunk: usize, data: &[f32]) -> Result<()> {
        assert!(data.len() <= self.chunk_size * self.dim);
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        std::fs::write(self.path(chunk), bytes)?;
        self.stats.writes.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Raw chunk read, tagged with the tier it was served from.
    pub fn read_chunk(&self, chunk: usize, tier: Tier) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.path(chunk))
            .with_context(|| format!("chunk {chunk} missing"))?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        match tier {
            Tier::Remote => {
                self.stats.remote_reads.fetch_add(1, Ordering::Relaxed);
                self.stats.virtual_cost.fetch_add(COST_REMOTE, Ordering::Relaxed);
            }
            Tier::Static => {
                self.stats.static_reads.fetch_add(1, Ordering::Relaxed);
                self.stats.virtual_cost.fetch_add(COST_STATIC, Ordering::Relaxed);
            }
        }
        Ok(data)
    }

    pub fn note_dynamic_hit(&self) {
        self.stats.dynamic_hits.fetch_add(1, Ordering::Relaxed);
        self.stats.virtual_cost.fetch_add(COST_DYNAMIC, Ordering::Relaxed);
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Remote,
    Static,
}

/// Peak resident footprint of a [`SpillScatter`] run: the largest number
/// of partially-assembled chunks (and their exact buffer bytes) alive at
/// any instant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpillPeak {
    pub bytes: usize,
    pub chunks: usize,
}

/// Streaming row scatter into a [`ChunkStore`] with a bounded resident
/// window — the disk-spill write path for layer embeddings (DESIGN.md
/// §13). Rows arrive in any order (the engine's workers stream blocks
/// concurrently); each chunk's buffer is allocated on first touch and
/// flushed through [`ChunkStore::write_chunk`] the moment its last row
/// lands, so the resident set is the partial-chunk frontier rather than
/// the full [n, dim] matrix. The on-disk bytes are independent of arrival
/// order, and flushing through `write_chunk` keeps the `writes` stat
/// accounting identical to the in-memory path (one tick per chunk).
pub struct SpillScatter<'a> {
    store: &'a ChunkStore,
    /// chunk → (row buffer, per-row seen bits, rows filled)
    partial: std::collections::HashMap<usize, (Vec<f32>, crate::util::bitset::BitSet, usize)>,
    rows_done: usize,
    resident_bytes: usize,
    peak: SpillPeak,
}

impl<'a> SpillScatter<'a> {
    pub fn new(store: &'a ChunkStore) -> Self {
        Self {
            store,
            partial: std::collections::HashMap::new(),
            rows_done: 0,
            resident_bytes: 0,
            peak: SpillPeak::default(),
        }
    }

    /// Place one row (len = dim). Errors on out-of-range rows and on a row
    /// written twice — the engine's worker vertex sets are disjoint, so a
    /// duplicate means a scatter-index bug, not a benign overwrite.
    pub fn put_row(&mut self, row: usize, data: &[f32]) -> Result<()> {
        anyhow::ensure!(data.len() == self.store.dim, "row width {} != dim {}", data.len(), self.store.dim);
        anyhow::ensure!(row < self.store.n_rows, "row {row} out of range ({} rows)", self.store.n_rows);
        let chunk = self.store.chunk_of_row(row);
        let rows_here = self.store.rows_in_chunk(chunk);
        let dim = self.store.dim;
        if !self.partial.contains_key(&chunk) {
            self.resident_bytes += rows_here * dim * 4;
        }
        let (buf, seen, filled) = self.partial.entry(chunk).or_insert_with(|| {
            (vec![0f32; rows_here * dim], crate::util::bitset::BitSet::new(rows_here), 0)
        });
        let slot = row - chunk * self.store.chunk_size;
        anyhow::ensure!(!seen.get(slot), "row {row} written twice (chunk {chunk})");
        seen.set(slot);
        buf[slot * dim..(slot + 1) * dim].copy_from_slice(data);
        *filled += 1;
        self.rows_done += 1;
        if self.resident_bytes > self.peak.bytes {
            self.peak.bytes = self.resident_bytes;
        }
        if self.partial.len() > self.peak.chunks {
            self.peak.chunks = self.partial.len();
        }
        if *filled == rows_here {
            let (buf, _, _) = self.partial.remove(&chunk).unwrap();
            self.store.write_chunk(chunk, &buf)?;
            self.resident_bytes -= rows_here * dim * 4;
        }
        Ok(())
    }

    /// Close the scatter: every row must have landed (so every chunk has
    /// flushed). Returns the peak resident window.
    pub fn finish(self) -> Result<SpillPeak> {
        anyhow::ensure!(
            self.partial.is_empty() && self.rows_done == self.store.n_rows,
            "spill scatter incomplete: {}/{} rows, {} partial chunks",
            self.rows_done,
            self.store.n_rows,
            self.partial.len()
        );
        Ok(self.peak)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("glisp_cs_{name}"));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn write_read_round_trip() {
        let cs = ChunkStore::create(tmp("rt"), 100, 16, 4).unwrap();
        assert_eq!(cs.num_chunks, 7);
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        cs.write_chunk(2, &data).unwrap();
        let back = cs.read_chunk(2, Tier::Static).unwrap();
        assert_eq!(back, data);
        assert_eq!(cs.stats.static_reads.load(Ordering::Relaxed), 1);
        assert_eq!(cs.stats.total_cost(), COST_STATIC);
    }

    #[test]
    fn cost_accounting_by_tier() {
        let cs = ChunkStore::create(tmp("cost"), 32, 16, 2).unwrap();
        cs.write_chunk(0, &[0.0; 32]).unwrap();
        cs.read_chunk(0, Tier::Remote).unwrap();
        cs.read_chunk(0, Tier::Static).unwrap();
        cs.note_dynamic_hit();
        assert_eq!(cs.stats.total_cost(), COST_REMOTE + COST_STATIC + COST_DYNAMIC);
        assert_eq!(cs.stats.chunk_reads(), 2);
        assert!((cs.stats.hit_ratio() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn missing_chunk_errors() {
        let cs = ChunkStore::create(tmp("miss"), 32, 16, 2).unwrap();
        assert!(cs.read_chunk(1, Tier::Remote).is_err());
    }

    /// Full matrix a chunk store holds, read back chunk-by-chunk.
    fn read_all(cs: &ChunkStore) -> Vec<f32> {
        let mut out = Vec::with_capacity(cs.n_rows * cs.dim);
        for c in 0..cs.num_chunks {
            out.extend(cs.read_chunk(c, Tier::Static).unwrap());
        }
        out
    }

    #[test]
    fn spill_scatter_any_order_matches_dense_write() {
        // Reference: write the dense [n, dim] matrix chunk-by-chunk.
        let n = 23;
        let dim = 3;
        let dense: Vec<f32> = (0..n * dim).map(|i| i as f32 * 0.5).collect();
        let a = ChunkStore::create(tmp("spill_a"), n, 4, dim).unwrap();
        for c in 0..a.num_chunks {
            let lo = c * 4 * dim;
            let hi = ((c + 1) * 4 * dim).min(dense.len());
            a.write_chunk(c, &dense[lo..hi]).unwrap();
        }
        // Spill path: same rows scattered in a shuffled order.
        let b = ChunkStore::create(tmp("spill_b"), n, 4, dim).unwrap();
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = crate::util::rng::SplitMix64::new(9);
        for i in (1..n).rev() {
            order.swap(i, (rng.next_u64() % (i as u64 + 1)) as usize);
        }
        let mut sc = SpillScatter::new(&b);
        for &r in &order {
            sc.put_row(r, &dense[r * dim..(r + 1) * dim]).unwrap();
        }
        let peak = sc.finish().unwrap();
        assert_eq!(read_all(&a), read_all(&b));
        // writes stat ticks once per chunk on both paths.
        assert_eq!(
            b.stats.writes.load(Ordering::Relaxed),
            a.stats.writes.load(Ordering::Relaxed)
        );
        // Shuffled arrival touches several chunks at once but the window
        // stays bounded by the chunk count and its exact buffer bytes.
        assert!(peak.chunks >= 1 && peak.chunks <= b.num_chunks);
        assert!(peak.bytes <= b.num_chunks * 4 * dim * 4);
    }

    #[test]
    fn spill_scatter_sequential_window_is_one_chunk() {
        let n = 64;
        let dim = 2;
        let cs = ChunkStore::create(tmp("spill_seq"), n, 8, dim).unwrap();
        let mut sc = SpillScatter::new(&cs);
        for r in 0..n {
            sc.put_row(r, &[r as f32, -(r as f32)]).unwrap();
        }
        let peak = sc.finish().unwrap();
        assert_eq!(peak.chunks, 1);
        assert_eq!(peak.bytes, 8 * dim * 4);
        assert_eq!(cs.stats.writes.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn spill_scatter_rejects_misuse() {
        let cs = ChunkStore::create(tmp("spill_err"), 8, 4, 2).unwrap();
        let mut sc = SpillScatter::new(&cs);
        assert!(sc.put_row(0, &[1.0]).is_err()); // wrong width
        assert!(sc.put_row(8, &[1.0, 2.0]).is_err()); // out of range
        sc.put_row(0, &[1.0, 2.0]).unwrap();
        assert!(sc.put_row(0, &[3.0, 4.0]).is_err()); // double write
        assert!(sc.finish().is_err()); // incomplete
    }
}
