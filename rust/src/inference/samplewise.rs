//! Naive samplewise inference — the Fig. 13 baseline. Each target vertex
//! (or edge endpoint) independently samples its K-hop tree and runs the
//! full K-layer forward, recomputing every overlapping neighbor embedding
//! from scratch. "Naive" = training mode without the engine's GNN slicing,
//! embedding cache or reorder (paper's wording).
//!
//! The batch path has two modes, mirroring the trainer's (DESIGN.md §7):
//! sync ([`SamplewiseRunner::run_vertex_embedding`]) and pipelined
//! ([`SamplewiseRunner::run_vertex_embedding_pipelined`]), which reuses the
//! coordinator's producer machinery (`pipeline::assemble_tensors`,
//! `pipeline::batch_rng`) to overlap local sampling + feature assembly with
//! the embed-artifact execution. Chunk RNG streams are derived per chunk
//! index, so both modes produce identical embeddings. (These local paths
//! sample the *local* graph directly — no sampling service, so the
//! service's `--server-workers`/`--shard-size` pool knobs do not apply;
//! the per-seed stream contract it relies on is stated in DESIGN.md §7/§9.)
//!
//! A third path ([`SamplewiseRunner::run_vertex_embedding_via`]) samples
//! through a `SamplingClient` instead of the local graph — the inference
//! mode of a socket deployment (`glisp infer --connect`, DESIGN.md §12),
//! where the graph lives in `glisp serve` processes and only K-hop trees
//! cross the wire. Chunk sampling streams are `client.split(chunk_index)`-
//! derived, so the embeddings are bit-identical for an in-process and a
//! remote service with the same seeds.

use anyhow::{Context, Result};

use crate::coordinator::features::FeatureStore;
use crate::coordinator::pipeline::{assemble_tensors, batch_rng, PipelineConfig};
use crate::graph::csr::{Graph, VId};
use crate::runtime::tensor::HostTensor;
use crate::runtime::Runtime;
use crate::sampling::algo_d;
use crate::sampling::request::{SampleConfig, PAD};
use crate::sampling::subgraph::sample_tree;
use crate::sampling::SamplingClient;
use crate::util::rng::Rng;

#[derive(Clone, Debug, Default)]
pub struct SamplewiseReport {
    pub model_secs: f64,
    /// Producer-side sampling + assembly seconds (summed across producers;
    /// overlapped with `model_secs` in pipelined mode).
    pub sample_secs: f64,
    /// Vertex-layer computations — the redundancy the layerwise engine
    /// eliminates (each tree slot at each layer costs one).
    pub vertices_computed: u64,
}

pub struct SamplewiseRunner<'g> {
    pub runtime: Runtime,
    pub features: FeatureStore,
    pub enc_params: Vec<HostTensor>,
    g: &'g Graph,
    /// Base seed of the per-chunk sampling streams (`pipeline::batch_rng`).
    sample_seed: u64,
    /// Chunks embedded so far — the chunk index both modes derive their
    /// sampling streams from.
    embed_counter: usize,
    batch: usize,
    fanouts: Vec<usize>,
    hidden: usize,
}

/// Sample a fanout-padded tree directly over the local graph (same
/// Algorithm D sampler as the service; see engine.rs for why inference
/// samples locally). Free function so pipelined producer threads can call
/// it with only `&Graph` + their own RNG.
fn sample_levels(
    g: &Graph,
    fanouts: &[usize],
    rng: &mut Rng,
    seeds: &[VId],
) -> (Vec<Vec<VId>>, Vec<Vec<f32>>) {
    let mut levels = vec![seeds.to_vec()];
    let mut masks = Vec::new();
    for &f in fanouts {
        let parents = levels.last().unwrap();
        let mut level = vec![PAD; parents.len() * f];
        let mut mask = vec![0f32; parents.len() * f];
        for (i, &p) in parents.iter().enumerate() {
            if p == PAD {
                continue;
            }
            let cand = g.out_neighbors(p);
            if cand.is_empty() {
                continue;
            }
            if cand.len() <= f {
                for (s, &c) in cand.iter().enumerate() {
                    level[i * f + s] = c;
                    mask[i * f + s] = 1.0;
                }
            } else {
                for (s, idx) in algo_d::sample(rng, cand.len(), f).into_iter().enumerate() {
                    level[i * f + s] = cand[idx];
                    mask[i * f + s] = 1.0;
                }
            }
        }
        levels.push(level);
        masks.push(mask);
    }
    (levels, masks)
}

fn real_slots(levels: &[Vec<VId>]) -> u64 {
    levels
        .iter()
        .map(|l| l.iter().filter(|&&v| v != PAD).count() as u64)
        .sum()
}

/// One producer-assembled embed chunk.
struct AssembledChunk {
    index: usize,
    /// Real (unpadded) seeds in the chunk.
    len: usize,
    features: Vec<HostTensor>,
    masks: Vec<HostTensor>,
    real_slots: u64,
    sample_secs: f64,
}

impl<'g> SamplewiseRunner<'g> {
    pub fn new(
        g: &'g Graph,
        runtime: Runtime,
        features: FeatureStore,
        enc_params: Vec<HostTensor>,
        seed: u64,
    ) -> Result<Self> {
        let spec = runtime.spec("sage_embed")?;
        let batch = spec.meta_usize("batch").context("meta.batch")?;
        let fanouts = spec.meta_usizes("fanouts").context("meta.fanouts")?;
        let hidden = spec.meta_usize("hidden").context("meta.hidden")?;
        Ok(Self {
            runtime,
            features,
            enc_params,
            g,
            sample_seed: seed,
            embed_counter: 0,
            batch,
            fanouts,
            hidden,
        })
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    /// Embed one full batch of seeds (padded with PAD if short); returns
    /// [batch, hidden] embeddings.
    pub fn embed_batch(&mut self, seeds: &[VId], report: &mut SamplewiseReport) -> Result<Vec<f32>> {
        assert!(seeds.len() <= self.batch);
        let mut padded = seeds.to_vec();
        padded.resize(self.batch, PAD);
        let idx = self.embed_counter as u64;
        self.embed_counter += 1;
        let mut rng = batch_rng(self.sample_seed, idx);
        // sample_secs covers sampling + tensor assembly (the producer-side
        // work in pipelined mode — same split there, so the two modes'
        // reports are comparable); model_secs covers only the execute.
        let t_s = crate::util::timer::Timer::start();
        let (levels, masks) = sample_levels(self.g, &self.fanouts, &mut rng, &padded);
        let (feats, mask_t) = assemble_tensors(&levels, &masks, &self.features);
        report.sample_secs += t_s.secs();

        let t_m = crate::util::timer::Timer::start();
        // K-layer forward touches every tree slot at every layer it
        // participates in; count real slots once per layer that
        // computes them (level l is recomputed (K - l) times).
        report.vertices_computed += real_slots(&levels);
        let mut inputs: Vec<HostTensor> = self.enc_params.clone();
        inputs.extend(feats);
        inputs.extend(mask_t);
        let out = self.runtime.execute("sage_embed", &inputs)?;
        report.model_secs += t_m.secs();
        Ok(out[0].as_f32().to_vec())
    }

    /// [`Self::embed_batch`], but sampling through a `SamplingClient`
    /// (local pool or `--connect`ed socket fleet) instead of the local
    /// graph. The chunk's sampling stream is split off the client by chunk
    /// index — deterministic replay, and the same bits whichever transport
    /// the client's servers sit behind.
    pub fn embed_batch_via(
        &mut self,
        client: &SamplingClient,
        seeds: &[VId],
        report: &mut SamplewiseReport,
    ) -> Result<Vec<f32>> {
        assert!(seeds.len() <= self.batch);
        let mut padded = seeds.to_vec();
        padded.resize(self.batch, PAD);
        let idx = self.embed_counter as u64;
        self.embed_counter += 1;
        let mut c = client.split(idx);
        let t_s = crate::util::timer::Timer::start();
        let tree = sample_tree(&mut c, &padded, &self.fanouts, &SampleConfig::default())?;
        let (feats, mask_t) = assemble_tensors(&tree.levels, &tree.masks, &self.features);
        report.sample_secs += t_s.secs();

        let t_m = crate::util::timer::Timer::start();
        report.vertices_computed += real_slots(&tree.levels);
        let mut inputs: Vec<HostTensor> = self.enc_params.clone();
        inputs.extend(feats);
        inputs.extend(mask_t);
        let out = self.runtime.execute("sage_embed", &inputs)?;
        report.model_secs += t_m.secs();
        Ok(out[0].as_f32().to_vec())
    }

    /// Full-graph vertex embedding through a sampling service — the
    /// samplewise inference mode of `glisp infer --connect`.
    pub fn run_vertex_embedding_via(
        &mut self,
        client: &SamplingClient,
        n: usize,
    ) -> Result<(Vec<f32>, SamplewiseReport)> {
        let mut report = SamplewiseReport::default();
        let mut out = vec![0f32; n * self.hidden];
        let ids: Vec<VId> = (0..n as VId).collect();
        for chunk in ids.chunks(self.batch) {
            let emb = self.embed_batch_via(client, chunk, &mut report)?;
            let base = chunk[0] as usize * self.hidden;
            out[base..base + chunk.len() * self.hidden]
                .copy_from_slice(&emb[..chunk.len() * self.hidden]);
        }
        Ok((out, report))
    }

    /// Full-graph vertex embedding, samplewise: loops every vertex.
    pub fn run_vertex_embedding(&mut self) -> Result<(Vec<f32>, SamplewiseReport)> {
        let mut report = SamplewiseReport::default();
        let mut out = vec![0f32; self.g.n * self.hidden];
        let ids: Vec<VId> = (0..self.g.n as VId).collect();
        for chunk in ids.chunks(self.batch) {
            let emb = self.embed_batch(chunk, &mut report)?;
            let base = chunk[0] as usize * self.hidden;
            out[base..base + chunk.len() * self.hidden]
                .copy_from_slice(&emb[..chunk.len() * self.hidden]);
        }
        Ok((out, report))
    }

    /// Full-graph vertex embedding with sampling + feature assembly
    /// pipelined onto producer threads; the embed artifact runs on the
    /// calling thread as chunks become ready. Chunk RNG streams are index-
    /// derived, so the output equals [`Self::run_vertex_embedding`] exactly
    /// — chunks write disjoint output ranges, so no ordered reassembly is
    /// needed here.
    pub fn run_vertex_embedding_pipelined(
        &mut self,
        pcfg: &PipelineConfig,
    ) -> Result<(Vec<f32>, SamplewiseReport)> {
        let mut report = SamplewiseReport::default();
        let hidden = self.hidden;
        let batch = self.batch;
        let n = self.g.n;
        let mut out = vec![0f32; n * hidden];
        let ids: Vec<VId> = (0..n as VId).collect();
        let chunks: Vec<Vec<VId>> = ids.chunks(batch).map(|c| c.to_vec()).collect();
        let total = chunks.len();
        let base = self.embed_counter;
        self.embed_counter += total;

        let producers = pcfg.producers.max(1);
        let depth = pcfg.queue_depth.max(1);
        let g = self.g;
        let fanouts = self.fanouts.clone();
        let features = self.features.clone();
        let sample_seed = self.sample_seed;
        let next = std::sync::atomic::AtomicUsize::new(0);

        std::thread::scope(|scope| -> Result<()> {
            // Channel inside the scope: an early error return drops the
            // receiver before the implicit join, unblocking producers
            // stuck in `send`.
            let (tx, rx) = std::sync::mpsc::sync_channel::<AssembledChunk>(depth * producers);
            for _ in 0..producers {
                let tx = tx.clone();
                let next = &next;
                let chunks = &chunks;
                let fanouts = &fanouts;
                let features = features.clone();
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    let mut padded = chunks[i].clone();
                    padded.resize(batch, PAD);
                    let mut rng = batch_rng(sample_seed, (base + i) as u64);
                    let t_s = crate::util::timer::Timer::start();
                    let (levels, masks) = sample_levels(g, fanouts, &mut rng, &padded);
                    let (feats, mask_t) = assemble_tensors(&levels, &masks, &features);
                    let chunk = AssembledChunk {
                        index: i,
                        len: chunks[i].len(),
                        features: feats,
                        masks: mask_t,
                        real_slots: real_slots(&levels),
                        sample_secs: t_s.secs(),
                    };
                    if tx.send(chunk).is_err() {
                        break;
                    }
                });
            }
            drop(tx);

            for _ in 0..total {
                let c = rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("samplewise producers exited early"))?;
                report.sample_secs += c.sample_secs;
                report.vertices_computed += c.real_slots;
                let t_m = crate::util::timer::Timer::start();
                let mut inputs: Vec<HostTensor> = self.enc_params.clone();
                inputs.extend(c.features);
                inputs.extend(c.masks);
                let r = self.runtime.execute("sage_embed", &inputs)?;
                report.model_secs += t_m.secs();
                let emb = r[0].as_f32();
                let off = c.index * batch * hidden;
                out[off..off + c.len * hidden].copy_from_slice(&emb[..c.len * hidden]);
            }
            Ok(())
        })?;
        Ok((out, report))
    }

    /// Link prediction, samplewise: embeds BOTH endpoints' trees per edge —
    /// the recomputation blow-up Fig. 13 shows (70.77× there).
    pub fn run_link_prediction(
        &mut self,
        edges: &[(VId, VId)],
        decode_params: &[HostTensor],
    ) -> Result<(Vec<f32>, SamplewiseReport)> {
        let mut report = SamplewiseReport::default();
        let spec = self.runtime.spec("link_decode")?;
        let db = spec.meta_usize("batch").context("meta.batch")?;
        let mut scores = Vec::with_capacity(edges.len());
        for chunk in edges.chunks(db.min(self.batch)) {
            let us: Vec<VId> = chunk.iter().map(|e| e.0).collect();
            let vs: Vec<VId> = chunk.iter().map(|e| e.1).collect();
            let eu = self.embed_batch(&us, &mut report)?;
            let ev = self.embed_batch(&vs, &mut report)?;
            // Pad the decode batch.
            let h = self.hidden;
            let mut u = vec![0f32; db * h];
            let mut v = vec![0f32; db * h];
            u[..chunk.len() * h].copy_from_slice(&eu[..chunk.len() * h]);
            v[..chunk.len() * h].copy_from_slice(&ev[..chunk.len() * h]);
            let t_m = crate::util::timer::Timer::start();
            let mut inputs = vec![
                HostTensor::f32(vec![db, h], u),
                HostTensor::f32(vec![db, h], v),
            ];
            inputs.extend(decode_params.iter().cloned());
            let out = self.runtime.execute("link_decode", &inputs)?;
            report.model_secs += t_m.secs();
            scores.extend_from_slice(&out[0].as_f32()[..chunk.len()]);
        }
        Ok((scores, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::inference::engine::{init_decode_params, init_encoder_params};

    fn runner(g: &Graph) -> SamplewiseRunner<'_> {
        let art = crate::test_artifacts_dir();
        let runtime = Runtime::load(&art).unwrap();
        let enc = init_encoder_params(&runtime, 3).unwrap();
        SamplewiseRunner::new(g, runtime, FeatureStore::unlabeled(64), enc, 5).unwrap()
    }

    #[test]
    fn embeds_all_vertices() {
        let mut rng = Rng::new(310);
        let g = generator::chung_lu(300, 2400, 2.1, &mut rng);
        let mut r = runner(&g);
        let (h, report) = r.run_vertex_embedding().unwrap();
        assert_eq!(h.len(), 300 * r.hidden());
        assert!(h.iter().all(|x| x.is_finite()));
        // Redundancy: every seed costs ~1 + f1 + f1·f2 slots, far above the
        // 2/vertex of the layerwise engine.
        assert!(report.vertices_computed > 10 * g.n as u64);
    }

    #[test]
    fn pipelined_embedding_is_bit_identical_to_sync() {
        let mut rng = Rng::new(312);
        let g = generator::chung_lu(300, 2400, 2.1, &mut rng);
        let mut sync = runner(&g);
        let (hs, rs) = sync.run_vertex_embedding().unwrap();
        let mut pipe = runner(&g);
        let pcfg = PipelineConfig {
            producers: 3,
            queue_depth: 2,
            ordered: true,
        };
        let (hp, rp) = pipe.run_vertex_embedding_pipelined(&pcfg).unwrap();
        assert_eq!(hs, hp, "pipelined embeddings must equal sync bit-for-bit");
        assert_eq!(rs.vertices_computed, rp.vertices_computed);
    }

    #[test]
    fn service_backed_embedding_is_deterministic_and_finite() {
        use crate::partition::{AdaDNE, Partitioner};
        use crate::sampling::SamplingService;

        let mut rng = Rng::new(313);
        let g = generator::chung_lu(300, 2400, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 2, 0);
        let svc = SamplingService::launch(&g, &ea, 1).unwrap();
        let client = svc.client(4);
        let mut r1 = runner(&g);
        let (h1, report) = r1.run_vertex_embedding_via(&client, g.n).unwrap();
        assert_eq!(h1.len(), 300 * r1.hidden());
        assert!(h1.iter().all(|x| x.is_finite()));
        assert!(report.vertices_computed > 0);
        // Replay with a fresh runner + fresh client at the same seed: the
        // split(chunk_index) streams make the embeddings reproduce exactly.
        let client2 = svc.client(4);
        let mut r2 = runner(&g);
        let (h2, _) = r2.run_vertex_embedding_via(&client2, g.n).unwrap();
        assert_eq!(h1, h2, "service-backed samplewise inference must replay bit-for-bit");
        svc.shutdown();
    }

    #[test]
    fn link_prediction_doubles_tree_work() {
        let mut rng = Rng::new(311);
        let g = generator::chung_lu(300, 2400, 2.1, &mut rng);
        let mut r = runner(&g);
        let dec = init_decode_params(&r.runtime, 9).unwrap();
        let edges: Vec<(VId, VId)> = (0..64u32)
            .filter(|&u| !g.out_neighbors(u).is_empty())
            .map(|u| (u, g.out_neighbors(u)[0]))
            .collect();
        let (scores, report) = r.run_link_prediction(&edges, &dec).unwrap();
        assert_eq!(scores.len(), edges.len());
        assert!(scores.iter().all(|&s| (0.0..=1.0).contains(&s)));
        assert!(report.vertices_computed > 2 * edges.len() as u64 * 10);
    }
}
