//! Naive samplewise inference — the Fig. 13 baseline. Each target vertex
//! (or edge endpoint) independently samples its K-hop tree and runs the
//! full K-layer forward, recomputing every overlapping neighbor embedding
//! from scratch. "Naive" = training mode without the engine's GNN slicing,
//! embedding cache or reorder (paper's wording).

use anyhow::{Context, Result};

use crate::coordinator::features::FeatureStore;
use crate::graph::csr::{Graph, VId};
use crate::runtime::tensor::HostTensor;
use crate::runtime::Runtime;
use crate::sampling::algo_d;
use crate::sampling::request::PAD;
use crate::util::rng::Rng;

#[derive(Clone, Debug, Default)]
pub struct SamplewiseReport {
    pub model_secs: f64,
    pub sample_secs: f64,
    /// Vertex-layer computations — the redundancy the layerwise engine
    /// eliminates (each tree slot at each layer costs one).
    pub vertices_computed: u64,
}

pub struct SamplewiseRunner<'g> {
    pub runtime: Runtime,
    pub features: FeatureStore,
    pub enc_params: Vec<HostTensor>,
    g: &'g Graph,
    rng: Rng,
    batch: usize,
    fanouts: Vec<usize>,
    hidden: usize,
}

impl<'g> SamplewiseRunner<'g> {
    pub fn new(
        g: &'g Graph,
        runtime: Runtime,
        features: FeatureStore,
        enc_params: Vec<HostTensor>,
        seed: u64,
    ) -> Result<Self> {
        let spec = runtime.spec("sage_embed")?;
        let batch = spec.meta_usize("batch").context("meta.batch")?;
        let fanouts = spec.meta_usizes("fanouts").context("meta.fanouts")?;
        let hidden = spec.meta_usize("hidden").context("meta.hidden")?;
        Ok(Self {
            runtime,
            features,
            enc_params,
            g,
            rng: Rng::new(seed),
            batch,
            fanouts,
            hidden,
        })
    }

    pub fn hidden(&self) -> usize {
        self.hidden
    }

    /// Sample a fanout-padded tree directly over the local graph (same
    /// Algorithm D sampler as the service; see engine.rs for why inference
    /// samples locally).
    fn sample_levels(&mut self, seeds: &[VId]) -> (Vec<Vec<VId>>, Vec<Vec<f32>>) {
        let mut levels = vec![seeds.to_vec()];
        let mut masks = Vec::new();
        for &f in &self.fanouts {
            let parents = levels.last().unwrap();
            let mut level = vec![PAD; parents.len() * f];
            let mut mask = vec![0f32; parents.len() * f];
            for (i, &p) in parents.iter().enumerate() {
                if p == PAD {
                    continue;
                }
                let cand = self.g.out_neighbors(p);
                if cand.is_empty() {
                    continue;
                }
                if cand.len() <= f {
                    for (s, &c) in cand.iter().enumerate() {
                        level[i * f + s] = c;
                        mask[i * f + s] = 1.0;
                    }
                } else {
                    for (s, idx) in algo_d::sample(&mut self.rng, cand.len(), f)
                        .into_iter()
                        .enumerate()
                    {
                        level[i * f + s] = cand[idx];
                        mask[i * f + s] = 1.0;
                    }
                }
            }
            levels.push(level);
            masks.push(mask);
        }
        (levels, masks)
    }

    /// Embed one full batch of seeds (padded with PAD if short); returns
    /// [batch, hidden] embeddings.
    pub fn embed_batch(&mut self, seeds: &[VId], report: &mut SamplewiseReport) -> Result<Vec<f32>> {
        assert!(seeds.len() <= self.batch);
        let mut padded = seeds.to_vec();
        padded.resize(self.batch, PAD);
        let t_s = crate::util::timer::Timer::start();
        let (levels, masks) = self.sample_levels(&padded);
        report.sample_secs += t_s.secs();

        let t_m = crate::util::timer::Timer::start();
        let din = self.features.din;
        let mut inputs: Vec<HostTensor> = self.enc_params.clone();
        for level in &levels {
            inputs.push(HostTensor::f32(vec![level.len(), din], self.features.batch(level)));
            // K-layer forward touches every tree slot at every layer it
            // participates in; count real slots once per layer that
            // computes them (level l is recomputed (K - l) times).
            let real = level.iter().filter(|&&v| v != PAD).count() as u64;
            report.vertices_computed += real;
        }
        for m in &masks {
            inputs.push(HostTensor::f32(vec![m.len()], m.clone()));
        }
        let out = self.runtime.execute("sage_embed", &inputs)?;
        report.model_secs += t_m.secs();
        Ok(out[0].as_f32().to_vec())
    }

    /// Full-graph vertex embedding, samplewise: loops every vertex.
    pub fn run_vertex_embedding(&mut self) -> Result<(Vec<f32>, SamplewiseReport)> {
        let mut report = SamplewiseReport::default();
        let mut out = vec![0f32; self.g.n * self.hidden];
        let ids: Vec<VId> = (0..self.g.n as VId).collect();
        for chunk in ids.chunks(self.batch) {
            let emb = self.embed_batch(chunk, &mut report)?;
            let base = chunk[0] as usize * self.hidden;
            out[base..base + chunk.len() * self.hidden]
                .copy_from_slice(&emb[..chunk.len() * self.hidden]);
        }
        Ok((out, report))
    }

    /// Link prediction, samplewise: embeds BOTH endpoints' trees per edge —
    /// the recomputation blow-up Fig. 13 shows (70.77× there).
    pub fn run_link_prediction(
        &mut self,
        edges: &[(VId, VId)],
        decode_params: &[HostTensor],
    ) -> Result<(Vec<f32>, SamplewiseReport)> {
        let mut report = SamplewiseReport::default();
        let spec = self.runtime.spec("link_decode")?;
        let db = spec.meta_usize("batch").context("meta.batch")?;
        let mut scores = Vec::with_capacity(edges.len());
        for chunk in edges.chunks(db.min(self.batch)) {
            let us: Vec<VId> = chunk.iter().map(|e| e.0).collect();
            let vs: Vec<VId> = chunk.iter().map(|e| e.1).collect();
            let eu = self.embed_batch(&us, &mut report)?;
            let ev = self.embed_batch(&vs, &mut report)?;
            // Pad the decode batch.
            let h = self.hidden;
            let mut u = vec![0f32; db * h];
            let mut v = vec![0f32; db * h];
            u[..chunk.len() * h].copy_from_slice(&eu[..chunk.len() * h]);
            v[..chunk.len() * h].copy_from_slice(&ev[..chunk.len() * h]);
            let t_m = crate::util::timer::Timer::start();
            let mut inputs = vec![
                HostTensor::f32(vec![db, h], u),
                HostTensor::f32(vec![db, h], v),
            ];
            inputs.extend(decode_params.iter().cloned());
            let out = self.runtime.execute("link_decode", &inputs)?;
            report.model_secs += t_m.secs();
            scores.extend_from_slice(&out[0].as_f32()[..chunk.len()]);
        }
        Ok((scores, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::inference::engine::{init_decode_params, init_encoder_params};

    fn runner(g: &Graph) -> SamplewiseRunner<'_> {
        let art = crate::test_artifacts_dir();
        let runtime = Runtime::load(&art).unwrap();
        let enc = init_encoder_params(&runtime, 3).unwrap();
        SamplewiseRunner::new(g, runtime, FeatureStore::unlabeled(64), enc, 5).unwrap()
    }

    #[test]
    fn embeds_all_vertices() {
        let mut rng = Rng::new(310);
        let g = generator::chung_lu(300, 2400, 2.1, &mut rng);
        let mut r = runner(&g);
        let (h, report) = r.run_vertex_embedding().unwrap();
        assert_eq!(h.len(), 300 * r.hidden());
        assert!(h.iter().all(|x| x.is_finite()));
        // Redundancy: every seed costs ~1 + f1 + f1·f2 slots, far above the
        // 2/vertex of the layerwise engine.
        assert!(report.vertices_computed > 10 * g.n as u64);
    }

    #[test]
    fn link_prediction_doubles_tree_work() {
        let mut rng = Rng::new(311);
        let g = generator::chung_lu(300, 2400, 2.1, &mut rng);
        let mut r = runner(&g);
        let dec = init_decode_params(&r.runtime, 9).unwrap();
        let edges: Vec<(VId, VId)> = (0..64u32)
            .filter(|&u| !g.out_neighbors(u).is_empty())
            .map(|u| (u, g.out_neighbors(u)[0]))
            .collect();
        let (scores, report) = r.run_link_prediction(&edges, &dec).unwrap();
        assert_eq!(scores.len(), edges.len());
        assert!(scores.iter().all(|&s| (0.0..=1.0).contains(&s)));
        assert!(report.vertices_computed > 2 * edges.len() as u64 * 10);
    }
}
