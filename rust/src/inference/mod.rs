//! Graph inference engine (paper §III-D): layerwise K-slice inference with
//! the two-level (static + dynamic) embedding cache over a chunked
//! simulated-DFS store, PDS reordering, and the samplewise baseline it is
//! measured against (Fig. 13–15, Table V).

pub mod chunk_store;
pub mod dynamic_cache;
pub mod engine;
pub mod samplewise;
pub mod static_cache;

pub use chunk_store::{ChunkStore, Tier};
pub use dynamic_cache::{DynamicCache, EvictPolicy};
pub use engine::{
    init_decode_params, init_encoder_params, EngineConfig, EngineReport, LayerwiseEngine,
    WorkerReport,
};
pub use samplewise::{SamplewiseReport, SamplewiseRunner};
pub use static_cache::CacheSystem;
