//! Layerwise graph inference engine (paper §III-D, Fig. 7). The K-layer
//! GNN is split into K one-layer slices; each slice sweeps every vertex
//! once, reading the previous layer's embeddings through the two-level
//! caching system and writing the next layer's chunks — eliminating the
//! K-hop recomputation of samplewise inference entirely.
//!
//! Workload allocation follows the partitioner (one worker per partition);
//! cache-local vertex ids come from the configured reorder algorithm
//! (PDS by default). Chunk reads/costs per tier are accounted in the
//! store stats (Fig. 14); the static fill is accounted per worker
//! (Table V).

use anyhow::{Context, Result};
use std::path::PathBuf;

use crate::coordinator::features::FeatureStore;
use crate::graph::csr::{Graph, VId};
use crate::graph::reorder::{rank_of, reorder, ReorderAlgo};
use crate::inference::chunk_store::ChunkStore;
use crate::inference::dynamic_cache::EvictPolicy;
use crate::inference::static_cache::CacheSystem;
use crate::partition::{primary_partition, EdgeAssignment};
use crate::runtime::tensor::HostTensor;
use crate::runtime::Runtime;
use crate::sampling::algo_d;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Embedding rows per DFS chunk.
    pub chunk_size: usize,
    /// Fraction of a worker's chunks held by the dynamic cache.
    pub dyn_cache_frac: f64,
    pub policy: EvictPolicy,
    pub reorder: ReorderAlgo,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            // The paper uses 32768-row chunks on 10^9-vertex graphs; 128
            // keeps the chunks-per-graph ratio comparable at bench scale.
            chunk_size: 128,
            dyn_cache_frac: 0.1,
            policy: EvictPolicy::Fifo,
            reorder: ReorderAlgo::PDS,
            seed: 17,
        }
    }
}

/// Per-block chunk memo over a CacheSystem: the engine's batched read path
/// (§Perf). Embedding IO is chunk-granular (Zarr semantics), so each block
/// takes one cache round-trip per *distinct chunk*, not per row — this
/// replaced per-row reads in the perf pass (EXPERIMENTS.md §Perf, ~4×).
struct BlockReader<'a> {
    cache: &'a mut CacheSystem,
    store: &'a ChunkStore,
    memo: std::collections::HashMap<usize, Vec<f32>>,
}

impl<'a> BlockReader<'a> {
    fn new(cache: &'a mut CacheSystem, store: &'a ChunkStore) -> Self {
        Self {
            cache,
            store,
            memo: std::collections::HashMap::new(),
        }
    }

    fn row(&mut self, row: usize, out: &mut [f32]) -> Result<()> {
        let chunk = self.store.chunk_of_row(row);
        if !self.memo.contains_key(&chunk) {
            let data = self.cache.get_chunk(self.store, chunk)?;
            self.memo.insert(chunk, data);
        } else {
            // Row served from memory without a chunk fetch — the "repeated
            // access in a short period" reuse PDS maximizes (paper §III-D);
            // counted as a dynamic-cache hit.
            self.store.note_dynamic_hit();
        }
        let data = &self.memo[&chunk];
        let off = (row - chunk * self.store.chunk_size) * self.store.dim;
        out.copy_from_slice(&data[off..off + self.store.dim]);
        Ok(())
    }
}

#[derive(Clone, Debug, Default)]
pub struct EngineReport {
    pub chunk_reads: u64,
    pub dynamic_hits: u64,
    pub virtual_cost: u64,
    pub fill_cost: u64,
    pub fill_chunks: u64,
    pub fill_secs: f64,
    pub model_secs: f64,
    pub dynamic_hit_ratio: f64,
    /// Vertex-layer computations performed (the redundancy metric).
    pub vertices_computed: u64,
}

pub struct LayerwiseEngine {
    pub runtime: Runtime,
    pub features: FeatureStore,
    /// 2-layer SAGE encoder params: [w_self, w_neigh, b] × 2.
    pub enc_params: Vec<HostTensor>,
    pub cfg: EngineConfig,
    // Geometry from the artifacts.
    block: usize,
    fanout: usize,
    hidden: usize,
    // Graph-derived state.
    n: usize,
    pub order: Vec<VId>,
    pub rank: Vec<u32>,
    part_of: Vec<u16>,
    num_parts: usize,
    /// Pre-sampled one-hop neighbors (global ids), fanout-padded per vertex.
    nbrs: Vec<VId>,
    work_dir: PathBuf,
}

impl LayerwiseEngine {
    pub fn new(
        g: &Graph,
        ea: &EdgeAssignment,
        runtime: Runtime,
        features: FeatureStore,
        enc_params: Vec<HostTensor>,
        cfg: EngineConfig,
        work_dir: PathBuf,
    ) -> Result<Self> {
        let l0 = runtime.spec("sage_infer_layer0")?;
        let block = l0.meta_usize("chunk").context("meta.chunk")?;
        let fanout = l0.meta_usize("fanout").context("meta.fanout")?;
        let l1 = runtime.spec("sage_infer_layer1")?;
        let hidden = l1.meta_usize("dout").context("meta.dout")?;
        anyhow::ensure!(enc_params.len() == 6, "encoder wants 6 param tensors");

        let part_of = primary_partition(g, ea);
        let order = reorder(g, cfg.reorder, &part_of);
        let rank = rank_of(&order);

        // Pre-sample one-hop neighbors once (paper: "precompute the one hop
        // sampled neighbors"); uniform Algorithm D, PAD-padded.
        let mut rng = Rng::new(cfg.seed);
        let mut nbrs = vec![crate::sampling::request::PAD; g.n * fanout];
        for v in 0..g.n {
            let cand = g.out_neighbors(v as VId);
            if cand.is_empty() {
                continue;
            }
            if cand.len() <= fanout {
                nbrs[v * fanout..v * fanout + cand.len()].copy_from_slice(cand);
            } else {
                for (s, i) in algo_d::sample(&mut rng, cand.len(), fanout)
                    .into_iter()
                    .enumerate()
                {
                    nbrs[v * fanout + s] = cand[i];
                }
            }
        }
        std::fs::create_dir_all(&work_dir)?;
        Ok(Self {
            runtime,
            features,
            enc_params,
            cfg,
            block,
            fanout,
            hidden,
            n: g.n,
            order,
            rank,
            part_of,
            num_parts: ea.num_parts,
            nbrs,
            work_dir,
        })
    }

    fn layer_params(&self, layer: usize) -> &[HostTensor] {
        &self.enc_params[layer * 3..layer * 3 + 3]
    }

    /// Worker w's vertices in rank order.
    fn worker_vertices(&self, w: usize) -> Vec<VId> {
        self.order
            .iter()
            .copied()
            .filter(|&v| self.part_of[v as usize] as usize == w)
            .collect()
    }

    /// Chunks worker w's layer reads touch: its vertices + their sampled
    /// neighbors (the static cache contents).
    fn worker_chunks(&self, verts: &[VId], store: &ChunkStore) -> Vec<usize> {
        let mut set = crate::util::bitset::BitSet::new(store.num_chunks);
        for &v in verts {
            set.set(store.chunk_of_row(self.rank[v as usize] as usize));
            for s in 0..self.fanout {
                let nb = self.nbrs[v as usize * self.fanout + s];
                if nb != crate::sampling::request::PAD {
                    set.set(store.chunk_of_row(self.rank[nb as usize] as usize));
                }
            }
        }
        set.iter_ones().collect()
    }

    fn write_all_chunks(&self, store: &ChunkStore, data: &[f32]) -> Result<()> {
        let per = store.chunk_size * store.dim;
        for c in 0..store.num_chunks {
            let a = c * per;
            let b = ((c + 1) * per).min(data.len());
            store.write_chunk(c, &data[a..b])?;
        }
        Ok(())
    }

    /// Full-graph vertex-embedding inference. Returns (final embeddings
    /// indexed by RANK, report).
    pub fn run_vertex_embedding(&mut self) -> Result<(Vec<f32>, EngineReport)> {
        let mut report = EngineReport::default();
        let din = self.features.din;

        // Layer-0 input store: features by rank, on "DFS".
        let f_store = ChunkStore::create(
            self.work_dir.join("layer_f"),
            self.n,
            self.cfg.chunk_size,
            din,
        )?;
        let feats_by_rank: Vec<f32> = {
            let vs: Vec<VId> = self.order.clone();
            self.features.batch(&vs)
        };
        self.write_all_chunks(&f_store, &feats_by_rank)?;
        drop(feats_by_rank);

        let h1_store = ChunkStore::create(
            self.work_dir.join("layer_h1"),
            self.n,
            self.cfg.chunk_size,
            self.hidden,
        )?;

        // ---- slice 0: features -> h1, slice 1: h1 -> h2 ----
        let mut h_out = vec![0f32; self.n * self.hidden];
        for layer in 0..2 {
            let (in_store, in_dim): (&ChunkStore, usize) = if layer == 0 {
                (&f_store, din)
            } else {
                (&h1_store, self.hidden)
            };
            let artifact = format!("sage_infer_layer{layer}");
            for w in 0..self.num_parts {
                let verts = self.worker_vertices(w);
                if verts.is_empty() {
                    continue;
                }
                // Static cache fill (Table V): the worker's chunk set. The
                // dynamic cache holds 10% of the worker's chunks (paper
                // §IV-E), floored so it is non-degenerate at bench scale.
                let t_fill = crate::util::timer::Timer::start();
                let worker_chunks = self.worker_chunks(&verts, in_store);
                let dyn_cap = ((worker_chunks.len() as f64 * self.cfg.dyn_cache_frac)
                    .ceil() as usize)
                    .max(4);
                let mut cache =
                    CacheSystem::new(in_store.num_chunks, dyn_cap, self.cfg.policy);
                cache.fill_static(worker_chunks.into_iter());
                report.fill_cost += cache.fill_cost;
                report.fill_chunks += cache.fill_chunks;
                report.fill_secs += t_fill.secs();

                let t_model = crate::util::timer::Timer::start();
                for block in verts.chunks(self.block) {
                    let mut h_self = vec![0f32; self.block * in_dim];
                    let mut h_neigh = vec![0f32; self.block * self.fanout * in_dim];
                    let mut mask = vec![0f32; self.block * self.fanout];
                    let mut reader = BlockReader::new(&mut cache, in_store);
                    for (i, &v) in block.iter().enumerate() {
                        reader.row(
                            self.rank[v as usize] as usize,
                            &mut h_self[i * in_dim..(i + 1) * in_dim],
                        )?;
                        for s in 0..self.fanout {
                            let nb = self.nbrs[v as usize * self.fanout + s];
                            if nb != crate::sampling::request::PAD {
                                let off = (i * self.fanout + s) * in_dim;
                                reader.row(
                                    self.rank[nb as usize] as usize,
                                    &mut h_neigh[off..off + in_dim],
                                )?;
                                mask[i * self.fanout + s] = 1.0;
                            }
                        }
                    }
                    drop(reader);
                    let mut inputs = vec![
                        HostTensor::f32(vec![self.block, in_dim], h_self),
                        HostTensor::f32(vec![self.block, self.fanout, in_dim], h_neigh),
                        HostTensor::f32(vec![self.block, self.fanout], mask),
                    ];
                    inputs.extend(self.layer_params(layer).iter().cloned());
                    let out = self.runtime.execute(&artifact, &inputs)?;
                    let data = out[0].as_f32();
                    for (i, &v) in block.iter().enumerate() {
                        let r = self.rank[v as usize] as usize;
                        h_out[r * self.hidden..(r + 1) * self.hidden]
                            .copy_from_slice(&data[i * self.hidden..(i + 1) * self.hidden]);
                    }
                    report.vertices_computed += block.len() as u64;
                }
                report.model_secs += t_model.secs();
                report.dynamic_hit_ratio = cache.dynamic_hit_ratio();
            }
            if layer == 0 {
                self.write_all_chunks(&h1_store, &h_out)?;
            }
        }

        // Aggregate store stats (feature + h1 reads).
        for st in [&f_store.stats, &h1_store.stats] {
            report.chunk_reads += st.chunk_reads();
            report.dynamic_hits += st.dynamic_hits.load(std::sync::atomic::Ordering::Relaxed);
            report.virtual_cost += st.total_cost();
        }
        report.dynamic_hit_ratio =
            report.dynamic_hits as f64 / (report.dynamic_hits + report.chunk_reads).max(1) as f64;
        Ok((h_out, report))
    }

    /// Link prediction over `edges` using cached final embeddings
    /// (layerwise path): two cache reads + one decode per edge.
    pub fn run_link_prediction(
        &mut self,
        h_final: &[f32],
        edges: &[(VId, VId)],
        decode_params: &[HostTensor],
    ) -> Result<(Vec<f32>, EngineReport)> {
        let mut report = EngineReport::default();
        let spec = self.runtime.spec("link_decode")?;
        let b = spec.meta_usize("batch").context("meta.batch")?;
        // Final embeddings as a chunked store read through the cache.
        let h2_store = ChunkStore::create(
            self.work_dir.join("layer_h2"),
            self.n,
            self.cfg.chunk_size,
            self.hidden,
        )?;
        self.write_all_chunks(&h2_store, h_final)?;
        let dyn_cap = ((h2_store.num_chunks as f64) * self.cfg.dyn_cache_frac).ceil() as usize;
        let mut cache = CacheSystem::new(h2_store.num_chunks, dyn_cap.max(1), self.cfg.policy);
        cache.fill_static(0..h2_store.num_chunks);
        report.fill_cost = cache.fill_cost;
        report.fill_chunks = cache.fill_chunks;

        let t_model = crate::util::timer::Timer::start();
        let mut scores = Vec::with_capacity(edges.len());
        for chunk in edges.chunks(b) {
            let mut u = vec![0f32; b * self.hidden];
            let mut v = vec![0f32; b * self.hidden];
            let mut reader = BlockReader::new(&mut cache, &h2_store);
            for (i, &(a, bb)) in chunk.iter().enumerate() {
                reader.row(
                    self.rank[a as usize] as usize,
                    &mut u[i * self.hidden..(i + 1) * self.hidden],
                )?;
                reader.row(
                    self.rank[bb as usize] as usize,
                    &mut v[i * self.hidden..(i + 1) * self.hidden],
                )?;
            }
            drop(reader);
            let mut inputs = vec![
                HostTensor::f32(vec![b, self.hidden], u),
                HostTensor::f32(vec![b, self.hidden], v),
            ];
            inputs.extend(decode_params.iter().cloned());
            let out = self.runtime.execute("link_decode", &inputs)?;
            scores.extend_from_slice(&out[0].as_f32()[..chunk.len()]);
        }
        report.model_secs = t_model.secs();
        report.chunk_reads = h2_store.stats.chunk_reads();
        report.dynamic_hits = h2_store
            .stats
            .dynamic_hits
            .load(std::sync::atomic::Ordering::Relaxed);
        report.virtual_cost = h2_store.stats.total_cost();
        report.dynamic_hit_ratio =
            report.dynamic_hits as f64 / (report.dynamic_hits + report.chunk_reads).max(1) as f64;
        Ok((scores, report))
    }
}

/// Glorot-style encoder/decoder parameter construction shared by the
/// engine, the samplewise baseline and the benches.
pub fn init_encoder_params(runtime: &Runtime, seed: u64) -> Result<Vec<HostTensor>> {
    let mut rng = Rng::new(seed);
    let mut params = Vec::new();
    for layer in 0..2 {
        let spec = runtime.spec(&format!("sage_infer_layer{layer}"))?;
        // inputs: h_self, h_neigh, mask, w_self, w_neigh, b
        let store = crate::coordinator::params::ParamStore::init_glorot(
            &spec.inputs[3..6],
            &mut rng,
        );
        params.extend(store.tensors);
    }
    Ok(params)
}

pub fn init_decode_params(runtime: &Runtime, seed: u64) -> Result<Vec<HostTensor>> {
    let mut rng = Rng::new(seed);
    let spec = runtime.spec("link_decode")?;
    Ok(crate::coordinator::params::ParamStore::init_glorot(&spec.inputs[2..6], &mut rng).tensors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::partition::{AdaDNE, Partitioner};

    fn setup(name: &str) -> (Graph, EdgeAssignment, PathBuf) {
        let mut rng = Rng::new(300);
        let g = generator::chung_lu(2000, 14_000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 2, 0);
        let dir = std::env::temp_dir().join(format!("glisp_eng_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        (g, ea, dir)
    }

    fn engine(g: &Graph, ea: &EdgeAssignment, dir: PathBuf) -> LayerwiseEngine {
        let art = crate::test_artifacts_dir();
        let runtime = Runtime::load(&art).unwrap();
        let enc = init_encoder_params(&runtime, 3).unwrap();
        LayerwiseEngine::new(
            g,
            ea,
            runtime,
            FeatureStore::unlabeled(64),
            enc,
            EngineConfig::default(),
            dir,
        )
        .unwrap()
    }

    #[test]
    fn vertex_embedding_covers_graph_once_per_layer() {
        let (g, ea, dir) = setup("cover");
        let mut eng = engine(&g, &ea, dir);
        let (h, report) = eng.run_vertex_embedding().unwrap();
        assert_eq!(h.len(), g.n * 128);
        // Layerwise = exactly 2 computations per vertex (one per slice).
        assert_eq!(report.vertices_computed, 2 * g.n as u64);
        assert!(h.iter().all(|x| x.is_finite()));
        assert!(report.chunk_reads > 0);
    }

    #[test]
    fn static_fill_guarantees_no_remote_reads() {
        let (g, ea, dir) = setup("noremote");
        let mut eng = engine(&g, &ea, dir.clone());
        let (_, report) = eng.run_vertex_embedding().unwrap();
        // All reads served from static or dynamic tiers: virtual cost must
        // be below all-remote cost.
        let all_remote = (report.chunk_reads + report.dynamic_hits)
            * crate::inference::chunk_store::COST_REMOTE;
        assert!(report.virtual_cost < all_remote / 2);
    }

    #[test]
    fn link_prediction_scores_in_range() {
        let (g, ea, dir) = setup("link");
        let mut eng = engine(&g, &ea, dir);
        let (h, _) = eng.run_vertex_embedding().unwrap();
        let dec = init_decode_params(&eng.runtime, 9).unwrap();
        let edges: Vec<(VId, VId)> = (0..g.n.min(300))
            .filter(|&u| !g.out_neighbors(u as VId).is_empty())
            .map(|u| (u as VId, g.out_neighbors(u as VId)[0]))
            .collect();
        let (scores, report) = eng.run_link_prediction(&h, &edges, &dec).unwrap();
        assert_eq!(scores.len(), edges.len());
        assert!(scores.iter().all(|&s| (0.0..=1.0).contains(&s)));
        assert!(report.dynamic_hit_ratio >= 0.0);
    }

    #[test]
    fn pds_reads_fewer_chunks_than_scrambled_order() {
        let (g, ea, dir) = setup("pds");
        let mut pds = engine(&g, &ea, dir.clone());
        let (_, rep_pds) = pds.run_vertex_embedding().unwrap();

        let art = crate::test_artifacts_dir();
        let runtime = Runtime::load(&art).unwrap();
        let enc = init_encoder_params(&runtime, 3).unwrap();
        let mut ns = LayerwiseEngine::new(
            &g,
            &ea,
            runtime,
            FeatureStore::unlabeled(64),
            enc,
            EngineConfig {
                reorder: crate::graph::reorder::ReorderAlgo::NS,
                ..Default::default()
            },
            dir.join("ns"),
        )
        .unwrap();
        let (_, rep_ns) = ns.run_vertex_embedding().unwrap();
        assert!(
            rep_pds.virtual_cost <= rep_ns.virtual_cost,
            "PDS cost {} should not exceed NS cost {}",
            rep_pds.virtual_cost,
            rep_ns.virtual_cost
        );
    }
}
