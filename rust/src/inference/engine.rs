//! Layerwise graph inference engine (paper §III-D, Fig. 7). The K-layer
//! GNN is split into K one-layer slices; each slice sweeps every vertex
//! once, reading the previous layer's embeddings through the two-level
//! caching system and writing the next layer's chunks — eliminating the
//! K-hop recomputation of samplewise inference entirely.
//!
//! Workload allocation follows the partitioner (one worker per partition),
//! and the partition sweeps of a slice run **concurrently** on scoped
//! worker threads: each worker owns a split [`Runtime`] handle and its own
//! [`CacheSystem`] over the shared (read-only, atomically-counted) input
//! [`ChunkStore`], and computes a disjoint set of output rows. A layer
//! barrier joins all workers before the next slice's input chunks are
//! published, so every slice reads a fully-materialized store — the
//! parallel sweep is bit-identical to the sequential one (DESIGN.md §8).
//!
//! Cache-local vertex ids come from the configured reorder algorithm
//! (PDS by default). Chunk reads/costs per tier are accounted in the
//! store stats (Fig. 14); the static fill is accounted per worker
//! (Table V, [`WorkerReport`]).

use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::features::FeatureStore;
use crate::graph::csr::{Graph, VId};
use crate::graph::reorder::{rank_of, reorder, ReorderAlgo};
use crate::inference::chunk_store::{ChunkStore, SpillPeak, SpillScatter};
use crate::inference::dynamic_cache::EvictPolicy;
use crate::inference::static_cache::CacheSystem;
use crate::partition::{primary_partition, EdgeAssignment};
use crate::runtime::tensor::HostTensor;
use crate::runtime::Runtime;
use crate::sampling::algo_d;
use crate::sampling::request::PAD;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// GNN depth K: the engine runs one slice per layer, resolving
    /// `sage_infer_layer{0..K}` from the manifest (which must carry the
    /// same depth — see `Runtime::load_with_layers`).
    pub layers: usize,
    /// Run each slice's partition sweeps on scoped worker threads (one
    /// per partition). Falls back to the sequential sweep when the
    /// backend cannot split; output is bit-identical either way.
    pub parallel: bool,
    /// Embedding rows per DFS chunk.
    pub chunk_size: usize,
    /// Fraction of a worker's chunks held by the dynamic cache.
    pub dyn_cache_frac: f64,
    pub policy: EvictPolicy,
    pub reorder: ReorderAlgo,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            layers: 2,
            parallel: true,
            // The paper uses 32768-row chunks on 10^9-vertex graphs; 128
            // keeps the chunks-per-graph ratio comparable at bench scale.
            chunk_size: 128,
            dyn_cache_frac: 0.1,
            policy: EvictPolicy::Fifo,
            reorder: ReorderAlgo::PDS,
            seed: 17,
        }
    }
}

/// Per-block chunk memo over a CacheSystem: the engine's batched read path
/// (§Perf). Embedding IO is chunk-granular (Zarr semantics), so each block
/// takes one cache round-trip per *distinct chunk*, not per row — this
/// replaced per-row reads in the perf pass (EXPERIMENTS.md §Perf, ~4×).
/// Memoized chunks share the cache's `Arc` allocation (no copies).
struct BlockReader<'a> {
    cache: &'a mut CacheSystem,
    store: &'a ChunkStore,
    memo: std::collections::HashMap<usize, Arc<Vec<f32>>>,
}

impl<'a> BlockReader<'a> {
    fn new(cache: &'a mut CacheSystem, store: &'a ChunkStore) -> Self {
        Self {
            cache,
            store,
            memo: std::collections::HashMap::new(),
        }
    }

    fn row(&mut self, row: usize, out: &mut [f32]) -> Result<()> {
        let chunk = self.store.chunk_of_row(row);
        if !self.memo.contains_key(&chunk) {
            let data = self.cache.get_chunk(self.store, chunk)?;
            self.memo.insert(chunk, data);
        } else {
            // Row served from memory without a chunk fetch — the "repeated
            // access in a short period" reuse PDS maximizes (paper §III-D);
            // counted as a dynamic-cache hit.
            self.store.note_dynamic_hit();
        }
        let data = &self.memo[&chunk];
        let off = (row - chunk * self.store.chunk_size) * self.store.dim;
        out.copy_from_slice(&data[off..off + self.store.dim]);
        Ok(())
    }
}

/// Per-worker accounting of one engine run (the Table V breakdown):
/// static-fill and model-execution costs plus the worker's dynamic-cache
/// behavior, summed across the K slices its thread executed.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    pub worker: usize,
    pub vertices_computed: u64,
    pub fill_chunks: u64,
    pub fill_cost: u64,
    pub fill_secs: f64,
    pub model_secs: f64,
    /// Chunk-granular dynamic-cache hits/misses of this worker's own
    /// [`CacheSystem`] (block-memo row reuse is counted in the shared
    /// store stats, not here).
    pub dynamic_hits: u64,
    pub dynamic_misses: u64,
}

impl WorkerReport {
    pub fn dynamic_hit_ratio(&self) -> f64 {
        let total = self.dynamic_hits + self.dynamic_misses;
        if total == 0 {
            0.0
        } else {
            self.dynamic_hits as f64 / total as f64
        }
    }

    fn merge(&mut self, other: &WorkerReport) {
        self.vertices_computed += other.vertices_computed;
        self.fill_chunks += other.fill_chunks;
        self.fill_cost += other.fill_cost;
        self.fill_secs += other.fill_secs;
        self.model_secs += other.model_secs;
        self.dynamic_hits += other.dynamic_hits;
        self.dynamic_misses += other.dynamic_misses;
    }
}

#[derive(Clone, Debug, Default)]
pub struct EngineReport {
    pub chunk_reads: u64,
    /// Chunk reads served by the static tier (subset of `chunk_reads`).
    pub static_reads: u64,
    /// Chunk reads that went remote (subset of `chunk_reads`).
    pub remote_reads: u64,
    pub dynamic_hits: u64,
    pub virtual_cost: u64,
    pub fill_cost: u64,
    pub fill_chunks: u64,
    /// Summed across workers (worker-seconds, not wall time).
    pub fill_secs: f64,
    /// Summed across workers (worker-seconds, not wall time).
    pub model_secs: f64,
    pub dynamic_hit_ratio: f64,
    /// Vertex-layer computations performed (the redundancy metric).
    pub vertices_computed: u64,
    /// Per-worker breakdown (empty for link prediction, which runs a
    /// single reader over the final store).
    pub workers: Vec<WorkerReport>,
    /// Disk-spill mode only: peak bytes (and chunk count) of
    /// partially-assembled output chunks resident at any instant, maxed
    /// across the K slices. 0 for the in-memory path.
    pub spill_peak_bytes: usize,
    pub spill_peak_chunks: usize,
}

impl EngineReport {
    /// Fraction of all cache accesses (chunk reads + dynamic hits) served
    /// by the static tier.
    pub fn static_hit_ratio(&self) -> f64 {
        let total = self.chunk_reads + self.dynamic_hits;
        if total == 0 {
            0.0
        } else {
            self.static_reads as f64 / total as f64
        }
    }

    /// Absorb one store's tier counters (shared by the sweep variants and
    /// the link path).
    fn absorb_store(&mut self, st: &crate::inference::chunk_store::StoreStats) {
        use std::sync::atomic::Ordering::Relaxed;
        self.chunk_reads += st.chunk_reads();
        self.static_reads += st.static_reads.load(Relaxed);
        self.remote_reads += st.remote_reads.load(Relaxed);
        self.dynamic_hits += st.dynamic_hits.load(Relaxed);
        self.virtual_cost += st.total_cost();
    }

    fn absorb(&mut self, rep: &WorkerReport) {
        self.fill_cost += rep.fill_cost;
        self.fill_chunks += rep.fill_chunks;
        self.fill_secs += rep.fill_secs;
        self.model_secs += rep.model_secs;
        self.vertices_computed += rep.vertices_computed;
        self.workers[rep.worker].merge(rep);
    }
}

/// One worker's finished slice sweep.
struct WorkerOutput {
    worker: usize,
    /// `[verts.len(), hidden]` output rows in worker-vertex order; the
    /// caller scatters them into the rank-indexed output buffer (workers
    /// own disjoint vertex sets, so the scatter targets are disjoint).
    local: Vec<f32>,
    rep: WorkerReport,
}

/// Chunks a worker's slice reads: its vertices plus their pre-sampled
/// neighbors — the static cache contents (Table V "fill cache" set).
fn worker_chunk_set(
    verts: &[VId],
    store: &ChunkStore,
    rank: &[u32],
    nbrs: &[VId],
    fanout: usize,
) -> Vec<usize> {
    let mut set = crate::util::bitset::BitSet::new(store.num_chunks);
    for &v in verts {
        set.set(store.chunk_of_row(rank[v as usize] as usize));
        for s in 0..fanout {
            let nb = nbrs[v as usize * fanout + s];
            if nb != PAD {
                set.set(store.chunk_of_row(rank[nb as usize] as usize));
            }
        }
    }
    set.iter_ones().collect()
}

/// One partition sweep of one slice: fill the worker's static cache, then
/// execute the slice artifact block by block. Pure function of the shared
/// read-only state — the parallel and sequential paths both call this, so
/// their outputs agree bit-for-bit by construction.
///
/// Output rows stream through `emit(start, rows)` as each block finishes:
/// `rows` is the flattened `[block_len, hidden]` result for
/// `verts[start..start + block_len]`. The in-memory path copies them into
/// a worker-local matrix ([`sweep_worker`]); the disk-spill path forwards
/// them straight to a [`SpillScatter`], so no worker ever holds more than
/// one block of output.
#[allow(clippy::too_many_arguments)]
fn sweep_worker_stream(
    runtime: &mut Runtime,
    cfg: &EngineConfig,
    artifact: &str,
    worker: usize,
    verts: &[VId],
    in_store: &ChunkStore,
    in_dim: usize,
    rank: &[u32],
    nbrs: &[VId],
    fanout: usize,
    block_rows: usize,
    hidden: usize,
    params: &[HostTensor],
    mut emit: impl FnMut(usize, &[f32]) -> Result<()>,
) -> Result<WorkerReport> {
    let mut rep = WorkerReport {
        worker,
        ..Default::default()
    };

    // Static cache fill (Table V): the worker's chunk set. The dynamic
    // cache holds 10% of the worker's chunks (paper §IV-E), floored so it
    // is non-degenerate at bench scale.
    let t_fill = Timer::start();
    let worker_chunks = worker_chunk_set(verts, in_store, rank, nbrs, fanout);
    let dyn_cap = ((worker_chunks.len() as f64 * cfg.dyn_cache_frac).ceil() as usize).max(4);
    let mut cache = CacheSystem::new(in_store.num_chunks, dyn_cap, cfg.policy);
    cache.fill_static(worker_chunks.into_iter());
    rep.fill_cost = cache.fill_cost;
    rep.fill_chunks = cache.fill_chunks;
    rep.fill_secs = t_fill.secs();

    let t_model = Timer::start();
    for (bi, block) in verts.chunks(block_rows).enumerate() {
        // Tail blocks execute at their true size (`execute_rows`), not
        // zero-padded to `block_rows`: no garbage rows through the
        // masked-mean aggregation, no wasted tail compute.
        let rows = block.len();
        let mut h_self = vec![0f32; rows * in_dim];
        let mut h_neigh = vec![0f32; rows * fanout * in_dim];
        let mut mask = vec![0f32; rows * fanout];
        let mut reader = BlockReader::new(&mut cache, in_store);
        for (i, &v) in block.iter().enumerate() {
            reader.row(
                rank[v as usize] as usize,
                &mut h_self[i * in_dim..(i + 1) * in_dim],
            )?;
            for s in 0..fanout {
                let nb = nbrs[v as usize * fanout + s];
                if nb != PAD {
                    let off = (i * fanout + s) * in_dim;
                    reader.row(rank[nb as usize] as usize, &mut h_neigh[off..off + in_dim])?;
                    mask[i * fanout + s] = 1.0;
                }
            }
        }
        drop(reader);
        let mut inputs = vec![
            HostTensor::f32(vec![rows, in_dim], h_self),
            HostTensor::f32(vec![rows, fanout, in_dim], h_neigh),
            HostTensor::f32(vec![rows, fanout], mask),
        ];
        inputs.extend(params.iter().cloned());
        // First 3 inputs (h_self, h_neigh, mask) are row-shaped.
        let out = runtime.execute_rows(artifact, rows, 3, &inputs)?;
        emit(bi * block_rows, &out[0].as_f32()[..rows * hidden])?;
        rep.vertices_computed += rows as u64;
    }
    rep.model_secs = t_model.secs();
    let (hits, misses) = cache.dynamic_counts();
    rep.dynamic_hits = hits;
    rep.dynamic_misses = misses;
    Ok(rep)
}

/// In-memory sweep: accumulate the streamed blocks into one
/// `[verts.len(), hidden]` worker-local matrix.
#[allow(clippy::too_many_arguments)]
fn sweep_worker(
    runtime: &mut Runtime,
    cfg: &EngineConfig,
    artifact: &str,
    worker: usize,
    verts: &[VId],
    in_store: &ChunkStore,
    in_dim: usize,
    rank: &[u32],
    nbrs: &[VId],
    fanout: usize,
    block_rows: usize,
    hidden: usize,
    params: &[HostTensor],
) -> Result<WorkerOutput> {
    let mut local = vec![0f32; verts.len() * hidden];
    let rep = sweep_worker_stream(
        runtime, cfg, artifact, worker, verts, in_store, in_dim, rank, nbrs, fanout, block_rows,
        hidden, params,
        |start, rows| {
            local[start * hidden..start * hidden + rows.len()].copy_from_slice(rows);
            Ok(())
        },
    )?;
    Ok(WorkerOutput {
        worker,
        local,
        rep,
    })
}

pub struct LayerwiseEngine {
    pub runtime: Runtime,
    pub features: FeatureStore,
    /// K-layer SAGE encoder params: [w_self, w_neigh, b] × K.
    pub enc_params: Vec<HostTensor>,
    pub cfg: EngineConfig,
    // Geometry from the artifacts.
    block: usize,
    fanout: usize,
    hidden: usize,
    // Graph-derived state.
    n: usize,
    pub order: Vec<VId>,
    pub rank: Vec<u32>,
    part_of: Vec<u16>,
    num_parts: usize,
    /// Pre-sampled one-hop neighbors (global ids), fanout-padded per vertex.
    nbrs: Vec<VId>,
    work_dir: PathBuf,
}

impl LayerwiseEngine {
    pub fn new(
        g: &Graph,
        ea: &EdgeAssignment,
        runtime: Runtime,
        features: FeatureStore,
        enc_params: Vec<HostTensor>,
        cfg: EngineConfig,
        work_dir: PathBuf,
    ) -> Result<Self> {
        anyhow::ensure!(cfg.layers >= 1, "engine needs at least one layer");
        let manifest_k = runtime.manifest.infer_layers();
        anyhow::ensure!(
            manifest_k == cfg.layers,
            "EngineConfig.layers = {} but the manifest carries a {manifest_k}-layer \
             inference encoder (load with Runtime::load_with_layers(dir, {}))",
            cfg.layers,
            cfg.layers
        );
        let l0 = runtime.spec("sage_infer_layer0")?;
        let block = l0.meta_usize("chunk").context("meta.chunk")?;
        let fanout = l0.meta_usize("fanout").context("meta.fanout")?;
        let hidden = l0.meta_usize("dout").context("meta.dout")?;
        for layer in 1..cfg.layers {
            let spec = runtime.spec(&format!("sage_infer_layer{layer}"))?;
            anyhow::ensure!(
                spec.meta_usize("chunk") == Some(block)
                    && spec.meta_usize("fanout") == Some(fanout),
                "sage_infer_layer{layer}: block/fanout geometry differs from layer 0"
            );
            let din = spec.meta_usize("din").context("meta.din")?;
            let dout = spec.meta_usize("dout").context("meta.dout")?;
            // The output buffer, the layer_h{k} stores and the scatter
            // slices all assume one uniform hidden width across slices.
            anyhow::ensure!(
                din == hidden && dout == hidden,
                "sage_infer_layer{layer}: din {din}/dout {dout} != uniform hidden {hidden}"
            );
        }
        anyhow::ensure!(
            enc_params.len() == 3 * cfg.layers,
            "encoder wants {} param tensors for {} layers, got {}",
            3 * cfg.layers,
            cfg.layers,
            enc_params.len()
        );

        let part_of = primary_partition(g, ea);
        let order = reorder(g, cfg.reorder, &part_of);
        let rank = rank_of(&order);

        // Pre-sample one-hop neighbors once (paper: "precompute the one hop
        // sampled neighbors"); uniform Algorithm D, PAD-padded.
        let mut rng = Rng::new(cfg.seed);
        let mut nbrs = vec![PAD; g.n * fanout];
        for v in 0..g.n {
            let cand = g.out_neighbors(v as VId);
            if cand.is_empty() {
                continue;
            }
            if cand.len() <= fanout {
                nbrs[v * fanout..v * fanout + cand.len()].copy_from_slice(cand);
            } else {
                for (s, i) in algo_d::sample(&mut rng, cand.len(), fanout)
                    .into_iter()
                    .enumerate()
                {
                    nbrs[v * fanout + s] = cand[i];
                }
            }
        }
        std::fs::create_dir_all(&work_dir)?;
        Ok(Self {
            runtime,
            features,
            enc_params,
            cfg,
            block,
            fanout,
            hidden,
            n: g.n,
            order,
            rank,
            part_of,
            num_parts: ea.num_parts,
            nbrs,
            work_dir,
        })
    }

    /// Worker w's vertices in rank order.
    fn worker_vertices(&self, w: usize) -> Vec<VId> {
        self.order
            .iter()
            .copied()
            .filter(|&v| self.part_of[v as usize] as usize == w)
            .collect()
    }

    /// Rows per `execute_rows` block, from the artifact geometry.
    pub fn block_rows(&self) -> usize {
        self.block
    }

    /// Pre-sampled neighbor fanout per vertex.
    pub fn fanout(&self) -> usize {
        self.fanout
    }

    /// Uniform hidden width of every slice.
    pub fn hidden(&self) -> usize {
        self.hidden
    }

    /// Global vertex count.
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// The pre-sampled one-hop neighbor snapshot (global ids, PAD-padded,
    /// `fanout` slots per vertex) every slice of this engine reads — the
    /// serving path follows the same snapshot so its per-row math is
    /// bit-identical to the offline sweep.
    pub fn neighbor_snapshot(&self) -> &[VId] {
        &self.nbrs
    }

    /// The engine's working directory (chunk stores live under it).
    pub fn work_dir(&self) -> &std::path::Path {
        &self.work_dir
    }

    pub(crate) fn write_all_chunks(&self, store: &ChunkStore, data: &[f32]) -> Result<()> {
        let per = store.chunk_size * store.dim;
        for c in 0..store.num_chunks {
            let a = c * per;
            let b = ((c + 1) * per).min(data.len());
            store.write_chunk(c, &data[a..b])?;
        }
        Ok(())
    }

    /// One slice's partition sweeps: concurrently on scoped worker threads
    /// when the backend splits (each worker moves its own `Runtime` handle
    /// and builds its own `CacheSystem`), sequentially otherwise. Workers
    /// are joined before this returns — the layer barrier.
    #[allow(clippy::too_many_arguments)]
    fn sweep_layer(
        runtime: &mut Runtime,
        cfg: &EngineConfig,
        artifact: &str,
        params: &[HostTensor],
        worker_verts: &[Vec<VId>],
        in_store: &ChunkStore,
        in_dim: usize,
        rank: &[u32],
        nbrs: &[VId],
        fanout: usize,
        block: usize,
        hidden: usize,
    ) -> Result<Vec<WorkerOutput>> {
        let active: Vec<usize> = (0..worker_verts.len())
            .filter(|&w| !worker_verts[w].is_empty())
            .collect();

        // One split runtime per worker, or a sequential fallback when the
        // backend cannot be shared (or there is nothing to overlap).
        let split_runtimes: Option<Vec<Runtime>> = if cfg.parallel && active.len() > 1 {
            let handles: Vec<Runtime> = active.iter().filter_map(|_| runtime.split()).collect();
            (handles.len() == active.len()).then_some(handles)
        } else {
            None
        };

        let Some(runtimes) = split_runtimes else {
            let mut outs = Vec::with_capacity(active.len());
            for &w in &active {
                outs.push(sweep_worker(
                    runtime,
                    cfg,
                    artifact,
                    w,
                    &worker_verts[w],
                    in_store,
                    in_dim,
                    rank,
                    nbrs,
                    fanout,
                    block,
                    hidden,
                    params,
                )?);
            }
            return Ok(outs);
        };

        std::thread::scope(|s| -> Result<Vec<WorkerOutput>> {
            let mut handles = Vec::with_capacity(active.len());
            for (mut rt, &w) in runtimes.into_iter().zip(&active) {
                let verts = worker_verts[w].as_slice();
                handles.push(s.spawn(move || -> Result<(WorkerOutput, u64)> {
                    let out = sweep_worker(
                        &mut rt, cfg, artifact, w, verts, in_store, in_dim, rank, nbrs,
                        fanout, block, hidden, params,
                    )?;
                    let execs = rt.executions.load(std::sync::atomic::Ordering::Relaxed);
                    Ok((out, execs))
                }));
            }
            let mut outs = Vec::with_capacity(handles.len());
            for h in handles {
                let (out, execs) = h
                    .join()
                    .map_err(|_| anyhow::anyhow!("inference worker thread panicked"))??;
                // Fold the split handle's execution count back into the
                // engine's runtime for accounting.
                runtime
                    .executions
                    .fetch_add(execs, std::sync::atomic::Ordering::Relaxed);
                outs.push(out);
            }
            Ok(outs)
        })
    }

    /// One slice's partition sweeps with disk-spilled output: workers
    /// stream finished blocks into a shared [`SpillScatter`] over
    /// `out_store` (a chunk flushes the moment its last row lands — rows
    /// cross partition boundaries, so the scatter is shared across
    /// workers, not per-worker). In the parallel mode blocks travel over a
    /// bounded channel (≤2 in flight per worker) and the main thread
    /// scatters; the on-disk bytes are arrival-order independent, so this
    /// is bit-identical to the sequential fallback and to the in-memory
    /// sweep.
    #[allow(clippy::too_many_arguments)]
    fn sweep_layer_spilled(
        runtime: &mut Runtime,
        cfg: &EngineConfig,
        artifact: &str,
        params: &[HostTensor],
        worker_verts: &[Vec<VId>],
        in_store: &ChunkStore,
        in_dim: usize,
        out_store: &ChunkStore,
        rank: &[u32],
        nbrs: &[VId],
        fanout: usize,
        block: usize,
        hidden: usize,
    ) -> Result<(Vec<WorkerReport>, SpillPeak)> {
        let active: Vec<usize> = (0..worker_verts.len())
            .filter(|&w| !worker_verts[w].is_empty())
            .collect();
        let mut spill = SpillScatter::new(out_store);
        let mut reports = Vec::with_capacity(active.len());

        let split_runtimes: Option<Vec<Runtime>> = if cfg.parallel && active.len() > 1 {
            let handles: Vec<Runtime> = active.iter().filter_map(|_| runtime.split()).collect();
            (handles.len() == active.len()).then_some(handles)
        } else {
            None
        };

        let Some(runtimes) = split_runtimes else {
            for &w in &active {
                let verts = worker_verts[w].as_slice();
                reports.push(sweep_worker_stream(
                    runtime,
                    cfg,
                    artifact,
                    w,
                    verts,
                    in_store,
                    in_dim,
                    rank,
                    nbrs,
                    fanout,
                    block,
                    hidden,
                    params,
                    |start, rows| {
                        for (i, row) in rows.chunks(hidden).enumerate() {
                            let r = rank[verts[start + i] as usize] as usize;
                            spill.put_row(r, row)?;
                        }
                        Ok(())
                    },
                )?);
            }
            let peak = spill.finish()?;
            return Ok((reports, peak));
        };

        // Bounded channel: at most 2 blocks per worker in flight, so the
        // streamed-output window is O(workers · block · hidden), never
        // O(n · hidden). Dropping the receiver on a scatter error unblocks
        // any sender, which then surfaces the error through its join.
        let (tx, rx) =
            std::sync::mpsc::sync_channel::<(usize, usize, Vec<f32>)>(2 * active.len());
        std::thread::scope(|s| -> Result<()> {
            let mut handles = Vec::with_capacity(active.len());
            for (mut rt, &w) in runtimes.into_iter().zip(&active) {
                let verts = worker_verts[w].as_slice();
                let tx = tx.clone();
                handles.push(s.spawn(move || -> Result<(WorkerReport, u64)> {
                    let rep = sweep_worker_stream(
                        &mut rt, cfg, artifact, w, verts, in_store, in_dim, rank, nbrs,
                        fanout, block, hidden, params,
                        |start, rows| {
                            tx.send((w, start, rows.to_vec()))
                                .map_err(|_| anyhow::anyhow!("spill scatter receiver gone"))
                        },
                    )?;
                    let execs = rt.executions.load(std::sync::atomic::Ordering::Relaxed);
                    Ok((rep, execs))
                }));
            }
            drop(tx);
            for (w, start, rows) in rx {
                for (i, row) in rows.chunks(hidden).enumerate() {
                    let r = rank[worker_verts[w][start + i] as usize] as usize;
                    spill.put_row(r, row)?;
                }
            }
            for h in handles {
                let (rep, execs) = h
                    .join()
                    .map_err(|_| anyhow::anyhow!("inference worker thread panicked"))??;
                runtime
                    .executions
                    .fetch_add(execs, std::sync::atomic::Ordering::Relaxed);
                reports.push(rep);
            }
            Ok(())
        })?;
        let peak = spill.finish()?;
        Ok((reports, peak))
    }

    /// Full-graph vertex-embedding inference. Returns (final embeddings
    /// indexed by RANK, report).
    pub fn run_vertex_embedding(&mut self) -> Result<(Vec<f32>, EngineReport)> {
        self.run_vertex_embedding_with(|_, _| Ok(()))
    }

    /// [`Self::run_vertex_embedding`] with a per-layer observer: after each
    /// slice's layer barrier, `on_layer(k, h)` receives slice k's complete
    /// rank-indexed `[n, hidden]` output (every layer, including the last).
    /// The sweep itself is unchanged — a no-op observer reproduces
    /// `run_vertex_embedding` exactly. The serving path uses this as its
    /// cache-warmup seam: every intermediate layer's activations pre-populate
    /// the per-layer serving slabs.
    pub fn run_vertex_embedding_with(
        &mut self,
        mut on_layer: impl FnMut(usize, &[f32]) -> Result<()>,
    ) -> Result<(Vec<f32>, EngineReport)> {
        let mut report = EngineReport {
            workers: (0..self.num_parts)
                .map(|w| WorkerReport {
                    worker: w,
                    ..Default::default()
                })
                .collect(),
            ..Default::default()
        };
        let din = self.features.din;
        let k_layers = self.cfg.layers;

        // Layer-0 input store: features by rank, on "DFS".
        let f_store = ChunkStore::create(
            self.work_dir.join("layer_f"),
            self.n,
            self.cfg.chunk_size,
            din,
        )?;
        // Chunked assembly: features are a pure function of the vertex id,
        // so the [n, din] matrix is derived one chunk at a time — the
        // resident window is a single chunk buffer in both this and the
        // spilled mode, and the chunk bytes are identical by construction.
        self.features
            .for_each_chunk(&self.order, self.cfg.chunk_size, |c, rows| {
                f_store.write_chunk(c, rows)
            })?;

        // One intermediate store per slice boundary: `layer_h{k}` holds
        // the activations entering slice k.
        let h_stores: Vec<ChunkStore> = (1..k_layers)
            .map(|k| {
                ChunkStore::create(
                    self.work_dir.join(format!("layer_h{k}")),
                    self.n,
                    self.cfg.chunk_size,
                    self.hidden,
                )
            })
            .collect::<Result<Vec<_>>>()?;

        // Worker partitions are fixed across slices.
        let worker_verts: Vec<Vec<VId>> =
            (0..self.num_parts).map(|w| self.worker_vertices(w)).collect();

        let mut h_out = vec![0f32; self.n * self.hidden];
        for layer in 0..k_layers {
            let (in_store, in_dim): (&ChunkStore, usize) = if layer == 0 {
                (&f_store, din)
            } else {
                (&h_stores[layer - 1], self.hidden)
            };
            let artifact = format!("sage_infer_layer{layer}");
            let outputs = Self::sweep_layer(
                &mut self.runtime,
                &self.cfg,
                &artifact,
                &self.enc_params[layer * 3..layer * 3 + 3],
                &worker_verts,
                in_store,
                in_dim,
                &self.rank,
                &self.nbrs,
                self.fanout,
                self.block,
                self.hidden,
            )?;
            for out in &outputs {
                // Scatter the worker's rows into the rank-indexed output;
                // partitions are disjoint, so no row is written twice.
                for (i, &v) in worker_verts[out.worker].iter().enumerate() {
                    let r = self.rank[v as usize] as usize;
                    h_out[r * self.hidden..(r + 1) * self.hidden]
                        .copy_from_slice(&out.local[i * self.hidden..(i + 1) * self.hidden]);
                }
                report.absorb(&out.rep);
            }
            on_layer(layer, &h_out)?;
            // Layer barrier: the next slice's input chunks are published
            // only after every worker finished this slice.
            if layer + 1 < k_layers {
                self.write_all_chunks(&h_stores[layer], &h_out)?;
            }
        }

        // Aggregate store stats (feature + every intermediate layer).
        for store in std::iter::once(&f_store).chain(h_stores.iter()) {
            report.absorb_store(&store.stats);
        }
        report.dynamic_hit_ratio =
            report.dynamic_hits as f64 / (report.dynamic_hits + report.chunk_reads).max(1) as f64;
        Ok((h_out, report))
    }

    /// Disk-spill variant of [`run_vertex_embedding`]: every layer's
    /// activations — including the final one — live in ChunkStore files,
    /// and no `[n, hidden]` matrix is ever resident. Worker blocks stream
    /// into a [`SpillScatter`] per slice; the peak partial-chunk window is
    /// reported in `spill_peak_bytes`/`spill_peak_chunks`. Bit-identical
    /// to the in-memory path: the returned store (`layer_h{K}`) holds
    /// exactly the bytes `run_vertex_embedding` returns, chunked.
    pub fn run_vertex_embedding_spilled(&mut self) -> Result<(ChunkStore, EngineReport)> {
        let mut report = EngineReport {
            workers: (0..self.num_parts)
                .map(|w| WorkerReport {
                    worker: w,
                    ..Default::default()
                })
                .collect(),
            ..Default::default()
        };
        let din = self.features.din;
        let k_layers = self.cfg.layers;

        let f_store = ChunkStore::create(
            self.work_dir.join("layer_f"),
            self.n,
            self.cfg.chunk_size,
            din,
        )?;
        self.features
            .for_each_chunk(&self.order, self.cfg.chunk_size, |c, rows| {
                f_store.write_chunk(c, rows)
            })?;

        // One store per slice OUTPUT: slice k writes `layer_h{k+1}`; the
        // last one is the returned final-embedding store (the same
        // directory `run_link_prediction` would build from a dense
        // `h_final`).
        let mut h_stores: Vec<ChunkStore> = (1..=k_layers)
            .map(|k| {
                ChunkStore::create(
                    self.work_dir.join(format!("layer_h{k}")),
                    self.n,
                    self.cfg.chunk_size,
                    self.hidden,
                )
            })
            .collect::<Result<Vec<_>>>()?;

        let worker_verts: Vec<Vec<VId>> =
            (0..self.num_parts).map(|w| self.worker_vertices(w)).collect();

        for layer in 0..k_layers {
            let (in_store, in_dim): (&ChunkStore, usize) = if layer == 0 {
                (&f_store, din)
            } else {
                (&h_stores[layer - 1], self.hidden)
            };
            let artifact = format!("sage_infer_layer{layer}");
            let (reps, peak) = Self::sweep_layer_spilled(
                &mut self.runtime,
                &self.cfg,
                &artifact,
                &self.enc_params[layer * 3..layer * 3 + 3],
                &worker_verts,
                in_store,
                in_dim,
                &h_stores[layer],
                &self.rank,
                &self.nbrs,
                self.fanout,
                self.block,
                self.hidden,
            )?;
            for rep in &reps {
                report.absorb(rep);
            }
            report.spill_peak_bytes = report.spill_peak_bytes.max(peak.bytes);
            report.spill_peak_chunks = report.spill_peak_chunks.max(peak.chunks);
        }

        for store in std::iter::once(&f_store).chain(h_stores.iter()) {
            report.absorb_store(&store.stats);
        }
        report.dynamic_hit_ratio =
            report.dynamic_hits as f64 / (report.dynamic_hits + report.chunk_reads).max(1) as f64;
        let final_store = h_stores.pop().expect("layers >= 1");
        Ok((final_store, report))
    }

    /// Link prediction over `edges` using cached final embeddings
    /// (layerwise path): two cache reads + one decode per edge.
    pub fn run_link_prediction(
        &mut self,
        h_final: &[f32],
        edges: &[(VId, VId)],
        decode_params: &[HostTensor],
    ) -> Result<(Vec<f32>, EngineReport)> {
        let mut report = EngineReport::default();
        let spec = self.runtime.spec("link_decode")?;
        let b = spec.meta_usize("batch").context("meta.batch")?;
        // Final embeddings as a chunked store read through the cache.
        let h_store = ChunkStore::create(
            self.work_dir.join(format!("layer_h{}", self.cfg.layers)),
            self.n,
            self.cfg.chunk_size,
            self.hidden,
        )?;
        self.write_all_chunks(&h_store, h_final)?;
        let dyn_cap = ((h_store.num_chunks as f64) * self.cfg.dyn_cache_frac).ceil() as usize;
        let mut cache = CacheSystem::new(h_store.num_chunks, dyn_cap.max(1), self.cfg.policy);
        cache.fill_static(0..h_store.num_chunks);
        report.fill_cost = cache.fill_cost;
        report.fill_chunks = cache.fill_chunks;

        let t_model = Timer::start();
        let mut scores = Vec::with_capacity(edges.len());
        for chunk in edges.chunks(b) {
            let rows = chunk.len();
            let mut u = vec![0f32; rows * self.hidden];
            let mut v = vec![0f32; rows * self.hidden];
            let mut reader = BlockReader::new(&mut cache, &h_store);
            for (i, &(a, bb)) in chunk.iter().enumerate() {
                reader.row(
                    self.rank[a as usize] as usize,
                    &mut u[i * self.hidden..(i + 1) * self.hidden],
                )?;
                reader.row(
                    self.rank[bb as usize] as usize,
                    &mut v[i * self.hidden..(i + 1) * self.hidden],
                )?;
            }
            drop(reader);
            let mut inputs = vec![
                HostTensor::f32(vec![rows, self.hidden], u),
                HostTensor::f32(vec![rows, self.hidden], v),
            ];
            inputs.extend(decode_params.iter().cloned());
            // Tail chunks decode at their true size; only emb_u/emb_v are
            // row-shaped (w1's leading dim collides with the batch size).
            let out = self.runtime.execute_rows("link_decode", rows, 2, &inputs)?;
            scores.extend_from_slice(out[0].as_f32());
        }
        report.model_secs = t_model.secs();
        report.absorb_store(&h_store.stats);
        report.dynamic_hit_ratio =
            report.dynamic_hits as f64 / (report.dynamic_hits + report.chunk_reads).max(1) as f64;
        Ok((scores, report))
    }
}

/// Glorot-style encoder/decoder parameter construction shared by the
/// engine, the samplewise baseline and the benches. Sizes itself from the
/// manifest's inference-encoder depth (`Manifest::infer_layers`), so a
/// K-layer runtime yields 3·K tensors.
pub fn init_encoder_params(runtime: &Runtime, seed: u64) -> Result<Vec<HostTensor>> {
    let layers = runtime.manifest.infer_layers();
    anyhow::ensure!(layers >= 1, "manifest carries no sage_infer_layer artifacts");
    let mut rng = Rng::new(seed);
    let mut params = Vec::new();
    for layer in 0..layers {
        let spec = runtime.spec(&format!("sage_infer_layer{layer}"))?;
        // inputs: h_self, h_neigh, mask, w_self, w_neigh, b
        let store = crate::coordinator::params::ParamStore::init_glorot(
            &spec.inputs[3..6],
            &mut rng,
        );
        params.extend(store.tensors);
    }
    Ok(params)
}

pub fn init_decode_params(runtime: &Runtime, seed: u64) -> Result<Vec<HostTensor>> {
    let mut rng = Rng::new(seed);
    let spec = runtime.spec("link_decode")?;
    Ok(crate::coordinator::params::ParamStore::init_glorot(&spec.inputs[2..6], &mut rng).tensors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator;
    use crate::partition::{AdaDNE, Partitioner};

    fn setup(name: &str) -> (Graph, EdgeAssignment, PathBuf) {
        let mut rng = Rng::new(300);
        let g = generator::chung_lu(2000, 14_000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 2, 0);
        let dir = std::env::temp_dir().join(format!("glisp_eng_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        (g, ea, dir)
    }

    fn engine(g: &Graph, ea: &EdgeAssignment, dir: PathBuf) -> LayerwiseEngine {
        let art = crate::test_artifacts_dir();
        let runtime = Runtime::load(&art).unwrap();
        let enc = init_encoder_params(&runtime, 3).unwrap();
        LayerwiseEngine::new(
            g,
            ea,
            runtime,
            FeatureStore::unlabeled(64),
            enc,
            EngineConfig::default(),
            dir,
        )
        .unwrap()
    }

    /// Engine with an arbitrary depth/threading config over the K-layer
    /// reference manifest.
    fn engine_k(
        g: &Graph,
        ea: &EdgeAssignment,
        dir: PathBuf,
        layers: usize,
        parallel: bool,
    ) -> LayerwiseEngine {
        let runtime = Runtime::load_with_layers(crate::test_artifacts_dir(), layers).unwrap();
        let enc = init_encoder_params(&runtime, 3).unwrap();
        LayerwiseEngine::new(
            g,
            ea,
            runtime,
            FeatureStore::unlabeled(64),
            enc,
            EngineConfig {
                layers,
                parallel,
                ..Default::default()
            },
            dir,
        )
        .unwrap()
    }

    #[test]
    fn vertex_embedding_covers_graph_once_per_layer() {
        let (g, ea, dir) = setup("cover");
        let mut eng = engine(&g, &ea, dir);
        let (h, report) = eng.run_vertex_embedding().unwrap();
        assert_eq!(h.len(), g.n * 128);
        // Layerwise = exactly 2 computations per vertex (one per slice).
        assert_eq!(report.vertices_computed, 2 * g.n as u64);
        assert!(h.iter().all(|x| x.is_finite()));
        assert!(report.chunk_reads > 0);
    }

    #[test]
    fn static_fill_guarantees_no_remote_reads() {
        let (g, ea, dir) = setup("noremote");
        let mut eng = engine(&g, &ea, dir.clone());
        let (_, report) = eng.run_vertex_embedding().unwrap();
        // All reads served from static or dynamic tiers: virtual cost must
        // be below all-remote cost.
        let all_remote = (report.chunk_reads + report.dynamic_hits)
            * crate::inference::chunk_store::COST_REMOTE;
        assert!(report.virtual_cost < all_remote / 2);
    }

    #[test]
    fn parallel_sweep_is_bit_identical_to_sequential_at_k3() {
        let mut rng = Rng::new(305);
        let g = generator::chung_lu(2400, 16_000, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 3, 0);
        let base = std::env::temp_dir().join("glisp_eng_k3");
        let _ = std::fs::remove_dir_all(&base);
        let mut par = engine_k(&g, &ea, base.join("par"), 3, true);
        let (hp, rp) = par.run_vertex_embedding().unwrap();
        let mut seq = engine_k(&g, &ea, base.join("seq"), 3, false);
        let (hs, rs) = seq.run_vertex_embedding().unwrap();

        assert_eq!(hp, hs, "worker-parallel sweep must be bit-identical");
        assert!(hp.iter().all(|x| x.is_finite()));
        assert_eq!(rp.vertices_computed, 3 * g.n as u64);
        assert_eq!(rs.vertices_computed, rp.vertices_computed);

        // Table V accounting survives the refactor: per-worker fills sum
        // to the aggregate, identically in both modes.
        let sum_par: u64 = rp.workers.iter().map(|w| w.fill_chunks).sum();
        let sum_seq: u64 = rs.workers.iter().map(|w| w.fill_chunks).sum();
        assert_eq!(sum_par, rp.fill_chunks);
        assert_eq!(sum_seq, rs.fill_chunks);
        assert_eq!(sum_par, sum_seq);
        // All three partitions did real work and report their own ratios.
        assert!(
            rp.workers
                .iter()
                .filter(|w| w.vertices_computed > 0)
                .count()
                >= 3
        );
    }

    #[test]
    fn tail_blocks_match_dense_reference_forward() {
        // Worker vertex counts are not multiples of the 256-row block:
        // tail blocks must execute at their true size and still produce
        // exactly the rows a dense full-graph forward produces.
        let mut rng = Rng::new(306);
        let g = generator::chung_lu(600, 4200, 2.1, &mut rng);
        let ea = AdaDNE::default().partition(&g, 2, 0);
        let dir = std::env::temp_dir().join("glisp_eng_tail");
        let _ = std::fs::remove_dir_all(&dir);
        let mut eng = engine(&g, &ea, dir);
        let (h, _) = eng.run_vertex_embedding().unwrap();
        assert!(h.iter().all(|x| x.is_finite()));

        // Dense single-shot forward over all n rows per slice: the same
        // per-row math with no blocking at all.
        let din = eng.features.din;
        let (n, f) = (g.n, eng.fanout);
        let mut prev: Vec<f32> = eng.features.batch(&eng.order);
        let mut prev_dim = din;
        for layer in 0..eng.cfg.layers {
            let mut h_neigh = vec![0f32; n * f * prev_dim];
            let mut mask = vec![0f32; n * f];
            for (r, &ov) in eng.order.iter().enumerate() {
                let v = ov as usize;
                for s in 0..f {
                    let nb = eng.nbrs[v * f + s];
                    if nb != PAD {
                        let nr = eng.rank[nb as usize] as usize;
                        h_neigh[(r * f + s) * prev_dim..][..prev_dim]
                            .copy_from_slice(&prev[nr * prev_dim..(nr + 1) * prev_dim]);
                        mask[r * f + s] = 1.0;
                    }
                }
            }
            let p = &eng.enc_params[layer * 3..layer * 3 + 3];
            let (mut z, _, _) = crate::runtime::reference::sage_layer_forward(
                &prev,
                &h_neigh,
                &mask,
                p[0].as_f32(),
                p[1].as_f32(),
                p[2].as_f32(),
                n,
                f,
                prev_dim,
                eng.hidden,
            );
            if layer + 1 < eng.cfg.layers {
                for v in z.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            prev = z;
            prev_dim = eng.hidden;
        }
        assert_eq!(h, prev, "engine output must bit-match the dense forward");
    }

    #[test]
    fn spilled_run_is_bit_identical_to_in_memory() {
        let (g, ea, dir) = setup("spill");
        let mut mem = engine(&g, &ea, dir.join("mem"));
        let (h, _) = mem.run_vertex_embedding().unwrap();

        let read_back = |store: &ChunkStore| -> Vec<f32> {
            let mut out = Vec::with_capacity(store.n_rows * store.dim);
            for c in 0..store.num_chunks {
                out.extend(
                    store
                        .read_chunk(c, crate::inference::chunk_store::Tier::Static)
                        .unwrap(),
                );
            }
            out
        };

        // Parallel spilled run: final store bytes == in-memory output.
        let mut sp = engine(&g, &ea, dir.join("sp"));
        let (store, rep) = sp.run_vertex_embedding_spilled().unwrap();
        assert_eq!(h, read_back(&store), "spilled bytes must bit-match");
        assert_eq!(rep.vertices_computed, 2 * g.n as u64);
        // The resident window never approached the full [n, hidden] matrix.
        assert!(rep.spill_peak_bytes > 0);
        assert!(
            rep.spill_peak_bytes < g.n * 128 * 4 / 2,
            "spill window {} should stay well below the {}-byte dense matrix",
            rep.spill_peak_bytes,
            g.n * 128 * 4
        );

        // Sequential spilled run agrees too (arrival-order independence).
        let mut sq = engine_k(&g, &ea, dir.join("sq"), 2, false);
        let (store_sq, _) = sq.run_vertex_embedding_spilled().unwrap();
        assert_eq!(h, read_back(&store_sq));
    }

    #[test]
    fn link_prediction_scores_in_range() {
        let (g, ea, dir) = setup("link");
        let mut eng = engine(&g, &ea, dir);
        let (h, _) = eng.run_vertex_embedding().unwrap();
        let dec = init_decode_params(&eng.runtime, 9).unwrap();
        let edges: Vec<(VId, VId)> = (0..g.n.min(300))
            .filter(|&u| !g.out_neighbors(u as VId).is_empty())
            .map(|u| (u as VId, g.out_neighbors(u as VId)[0]))
            .collect();
        let (scores, report) = eng.run_link_prediction(&h, &edges, &dec).unwrap();
        assert_eq!(scores.len(), edges.len());
        assert!(scores.iter().all(|&s| (0.0..=1.0).contains(&s)));
        assert!(report.dynamic_hit_ratio >= 0.0);
    }

    #[test]
    fn pds_reads_fewer_chunks_than_scrambled_order() {
        let (g, ea, dir) = setup("pds");
        let mut pds = engine(&g, &ea, dir.clone());
        let (_, rep_pds) = pds.run_vertex_embedding().unwrap();

        let art = crate::test_artifacts_dir();
        let runtime = Runtime::load(&art).unwrap();
        let enc = init_encoder_params(&runtime, 3).unwrap();
        let mut ns = LayerwiseEngine::new(
            &g,
            &ea,
            runtime,
            FeatureStore::unlabeled(64),
            enc,
            EngineConfig {
                reorder: crate::graph::reorder::ReorderAlgo::NS,
                ..Default::default()
            },
            dir.join("ns"),
        )
        .unwrap();
        let (_, rep_ns) = ns.run_vertex_embedding().unwrap();
        assert!(
            rep_pds.virtual_cost <= rep_ns.virtual_cost,
            "PDS cost {} should not exceed NS cost {}",
            rep_pds.virtual_cost,
            rep_ns.virtual_cost
        );
    }

    #[test]
    fn depth_mismatch_is_a_construction_error() {
        let (g, ea, dir) = setup("depth");
        // 3-layer manifest, 2-layer config: refused up front.
        let runtime = Runtime::load_with_layers(crate::test_artifacts_dir(), 3).unwrap();
        let enc = init_encoder_params(&runtime, 3).unwrap();
        let err = LayerwiseEngine::new(
            &g,
            &ea,
            runtime,
            FeatureStore::unlabeled(64),
            enc,
            EngineConfig::default(),
            dir,
        );
        assert!(err.is_err());
    }
}
