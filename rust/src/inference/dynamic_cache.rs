//! Second-level dynamic in-memory chunk cache (paper §III-D): absorbs the
//! repeated reads layerwise inference converts recomputation into. FIFO or
//! LRU eviction; the paper measures both (Fig. 15b) and ships FIFO.
//!
//! Chunks are held as `Arc<Vec<f32>>` so a hit hands out a reference to
//! the cached allocation instead of cloning the whole `[chunk_size, dim]`
//! buffer — the engine's `BlockReader` pays zero copies per dynamic hit.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictPolicy {
    Fifo,
    Lru,
}

pub struct DynamicCache {
    capacity: usize,
    policy: EvictPolicy,
    map: HashMap<usize, Arc<Vec<f32>>>,
    /// FIFO: insertion order. LRU: recency order (front = oldest).
    queue: VecDeque<usize>,
    pub hits: u64,
    pub misses: u64,
}

impl DynamicCache {
    pub fn new(capacity: usize, policy: EvictPolicy) -> Self {
        Self {
            capacity: capacity.max(1),
            policy,
            map: HashMap::new(),
            queue: VecDeque::new(),
            hits: 0,
            misses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn get(&mut self, chunk: usize) -> Option<&Arc<Vec<f32>>> {
        if self.map.contains_key(&chunk) {
            self.hits += 1;
            if self.policy == EvictPolicy::Lru {
                // Move to the back (most recent). O(n) scan is fine at the
                // few-thousand-chunk scale of the simulation.
                if let Some(pos) = self.queue.iter().position(|&c| c == chunk) {
                    self.queue.remove(pos);
                    self.queue.push_back(chunk);
                }
            }
            self.map.get(&chunk)
        } else {
            self.misses += 1;
            None
        }
    }

    pub fn insert(&mut self, chunk: usize, data: Arc<Vec<f32>>) {
        if self.map.contains_key(&chunk) {
            return;
        }
        if self.map.len() == self.capacity {
            if let Some(victim) = self.queue.pop_front() {
                self.map.remove(&victim);
            }
        }
        self.map.insert(chunk, data);
        self.queue.push_back(chunk);
    }

    pub fn hit_ratio(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_evicts_insertion_order() {
        let mut c = DynamicCache::new(2, EvictPolicy::Fifo);
        c.insert(1, Arc::new(vec![1.0]));
        c.insert(2, Arc::new(vec![2.0]));
        assert!(c.get(1).is_some()); // access does not protect under FIFO
        c.insert(3, Arc::new(vec![3.0])); // evicts 1
        assert!(c.get(1).is_none());
        assert!(c.get(2).is_some());
        assert!(c.get(3).is_some());
    }

    #[test]
    fn lru_protects_recently_used() {
        let mut c = DynamicCache::new(2, EvictPolicy::Lru);
        c.insert(1, Arc::new(vec![1.0]));
        c.insert(2, Arc::new(vec![2.0]));
        assert!(c.get(1).is_some()); // 1 becomes most recent
        c.insert(3, Arc::new(vec![3.0])); // evicts 2
        assert!(c.get(1).is_some());
        assert!(c.get(2).is_none());
    }

    #[test]
    fn hit_ratio_counts() {
        let mut c = DynamicCache::new(4, EvictPolicy::Fifo);
        c.insert(0, Arc::new(vec![]));
        c.get(0);
        c.get(9);
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert!((c.hit_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn capacity_bound_holds() {
        let mut c = DynamicCache::new(3, EvictPolicy::Fifo);
        for i in 0..100 {
            c.insert(i, Arc::new(vec![i as f32]));
            assert!(c.len() <= 3);
        }
    }

    #[test]
    fn duplicate_insert_is_noop() {
        let mut c = DynamicCache::new(2, EvictPolicy::Fifo);
        c.insert(1, Arc::new(vec![1.0]));
        c.insert(1, Arc::new(vec![9.0]));
        assert_eq!(c.get(1).unwrap()[0], 1.0);
        assert_eq!(c.len(), 1);
    }
}
