//! Per-worker two-level cache view over a chunk store (paper §III-D).
//!
//! Level 1 (**static**): the chunks covering this worker's partition
//! vertices plus the pre-sampled neighbors of its boundary vertices —
//! filled before each layer's inference, guaranteeing a 100% local hit
//! ratio for the layer's reads. Level 2 (**dynamic**): an in-memory
//! FIFO/LRU chunk cache absorbing repeated reads.

use std::sync::Arc;

use anyhow::Result;

use crate::inference::chunk_store::{ChunkStore, Tier};
use crate::inference::dynamic_cache::{DynamicCache, EvictPolicy};
use crate::util::bitset::BitSet;

pub struct CacheSystem {
    /// Chunks resident in this worker's static (local disk) cache.
    static_chunks: BitSet,
    dynamic: DynamicCache,
    pub fill_cost: u64,
    pub fill_chunks: u64,
}

impl CacheSystem {
    pub fn new(num_chunks: usize, dyn_capacity: usize, policy: EvictPolicy) -> Self {
        Self {
            static_chunks: BitSet::new(num_chunks),
            dynamic: DynamicCache::new(dyn_capacity, policy),
            fill_cost: 0,
            fill_chunks: 0,
        }
    }

    /// Mark + account the static fill for `chunks` (each fetched once from
    /// the DFS at remote cost — the Table V "fill cache" phase).
    pub fn fill_static(&mut self, chunks: impl Iterator<Item = usize>) {
        for c in chunks {
            if !self.static_chunks.get(c) {
                self.static_chunks.set(c);
                self.fill_cost += crate::inference::chunk_store::COST_REMOTE;
                self.fill_chunks += 1;
            }
        }
    }

    /// Read one embedding row through the cache hierarchy.
    pub fn read_row(&mut self, store: &ChunkStore, row: usize) -> Result<Vec<f32>> {
        let chunk = store.chunk_of_row(row);
        let offset = (row - chunk * store.chunk_size) * store.dim;
        if let Some(data) = self.dynamic.get(chunk) {
            store.note_dynamic_hit();
            return Ok(data[offset..offset + store.dim].to_vec());
        }
        let tier = if self.static_chunks.get(chunk) {
            Tier::Static
        } else {
            Tier::Remote
        };
        let data = Arc::new(store.read_chunk(chunk, tier)?);
        let out = data[offset..offset + store.dim].to_vec();
        self.dynamic.insert(chunk, data);
        Ok(out)
    }

    /// Fetch a whole chunk through the hierarchy — the engine's batched
    /// read path (§Perf): embedding IO is chunk-granular (Zarr semantics),
    /// so a block of rows fetches each distinct chunk once instead of
    /// taking one cache round-trip per row. A dynamic hit shares the
    /// cached allocation (`Arc`) — no chunk-sized copy on either the hit
    /// or the insert path.
    pub fn get_chunk(&mut self, store: &ChunkStore, chunk: usize) -> Result<Arc<Vec<f32>>> {
        if let Some(data) = self.dynamic.get(chunk) {
            store.note_dynamic_hit();
            return Ok(Arc::clone(data));
        }
        let tier = if self.static_chunks.get(chunk) {
            Tier::Static
        } else {
            Tier::Remote
        };
        let data = Arc::new(store.read_chunk(chunk, tier)?);
        self.dynamic.insert(chunk, Arc::clone(&data));
        Ok(data)
    }

    pub fn dynamic_hit_ratio(&self) -> f64 {
        self.dynamic.hit_ratio()
    }

    /// (hits, misses) of the dynamic tier — the per-worker numbers the
    /// engine folds into its `EngineReport` breakdown.
    pub fn dynamic_counts(&self) -> (u64, u64) {
        (self.dynamic.hits, self.dynamic.misses)
    }

    pub fn reset_dynamic(&mut self) {
        self.dynamic.reset_stats();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::chunk_store::{COST_DYNAMIC, COST_REMOTE, COST_STATIC};

    fn store(name: &str) -> ChunkStore {
        let dir = std::env::temp_dir().join(format!("glisp_sc_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        let cs = ChunkStore::create(dir, 64, 8, 2).unwrap();
        for c in 0..8 {
            let data: Vec<f32> = (0..16).map(|i| (c * 100 + i) as f32).collect();
            cs.write_chunk(c, &data).unwrap();
        }
        cs
    }

    #[test]
    fn read_row_values_correct() {
        let cs = store("vals");
        let mut sys = CacheSystem::new(8, 2, EvictPolicy::Fifo);
        let row = sys.read_row(&cs, 9).unwrap(); // chunk 1, row 1
        assert_eq!(row, vec![102.0, 103.0]);
    }

    #[test]
    fn tier_selection_and_costs() {
        let cs = store("tiers");
        let mut sys = CacheSystem::new(8, 1, EvictPolicy::Fifo);
        sys.fill_static(std::iter::once(0));
        assert_eq!(sys.fill_cost, COST_REMOTE);
        sys.read_row(&cs, 0).unwrap(); // static read
        sys.read_row(&cs, 1).unwrap(); // dynamic hit (same chunk)
        sys.read_row(&cs, 63).unwrap(); // chunk 7: not static => remote
        assert_eq!(
            cs.stats.total_cost(),
            COST_STATIC + COST_DYNAMIC + COST_REMOTE
        );
    }

    #[test]
    fn full_static_fill_means_no_remote_reads() {
        let cs = store("full");
        let mut sys = CacheSystem::new(8, 2, EvictPolicy::Fifo);
        sys.fill_static(0..8);
        for row in 0..64 {
            sys.read_row(&cs, row).unwrap();
        }
        assert_eq!(cs.stats.remote_reads.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn get_chunk_hits_share_the_cached_allocation() {
        let cs = store("arc");
        let mut sys = CacheSystem::new(8, 2, EvictPolicy::Fifo);
        sys.fill_static(std::iter::once(0));
        let first = sys.get_chunk(&cs, 0).unwrap();
        let second = sys.get_chunk(&cs, 0).unwrap();
        // The hit hands back the same allocation — no chunk-sized copy.
        assert!(Arc::ptr_eq(&first, &second));
        // Cost counters agree: one static fetch, then a dynamic hit; a
        // copying path would have to re-read the chunk instead.
        assert_eq!(cs.stats.chunk_reads(), 1);
        assert_eq!(
            cs.stats.dynamic_hits.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(cs.stats.total_cost(), COST_STATIC + COST_DYNAMIC);
        let (hits, misses) = sys.dynamic_counts();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn locality_raises_dynamic_hit_ratio() {
        let cs = store("local");
        // Sequential rows (high locality) vs striding across chunks.
        let mut seq = CacheSystem::new(8, 2, EvictPolicy::Fifo);
        for row in 0..64 {
            seq.read_row(&cs, row).unwrap();
        }
        let mut stride = CacheSystem::new(8, 2, EvictPolicy::Fifo);
        for i in 0..64 {
            stride.read_row(&cs, (i * 8 + i / 8) % 64).unwrap();
        }
        assert!(seq.dynamic_hit_ratio() > stride.dynamic_hit_ratio());
    }
}
