//! Quickstart: the whole GLISP stack in ~60 lines.
//!
//! Generates a small power-law graph, partitions it with AdaDNE, launches
//! the Gather-Apply sampling service, trains a 3-layer GraphSAGE for 20
//! steps, and prints the loss. Runs out of the box on the pure-Rust
//! reference backend; after `make artifacts`, build with `--features
//! pjrt` to execute the AOT HLO artifacts on PJRT instead.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use glisp::coordinator::{Batcher, FeatureStore, PipelineConfig, Trainer, TrainerConfig};
use glisp::graph::generator;
use glisp::partition::{quality, AdaDNE, Partitioner};
use glisp::runtime::Runtime;
use glisp::sampling::SamplingService;
use glisp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. A labeled synthetic graph: 5k vertices, 60k edges, 8 communities.
    let mut rng = Rng::new(42);
    let g = generator::labeled_community_graph(5_000, 60_000, 8, 0.9, &mut rng);
    let labels = Arc::new(g.label.clone());
    println!("graph: {} vertices, {} edges", g.n, g.m());

    // 2. Vertex-cut partitioning with AdaDNE (the paper's contribution).
    let ea = AdaDNE::default().partition(&g, 2, 1);
    let q = quality(&g, &ea);
    println!("AdaDNE: RF={:.3} VB={:.3} EB={:.3}", q.rf, q.vb, q.eb);

    // 3. Launch one sampling server per partition (Gather-Apply).
    let service = SamplingService::launch(&g, &ea, 1);

    // 4. A trainer wired to the AOT GraphSAGE train-step artifact.
    let features = FeatureStore::labeled(64, labels.clone(), 8, 0.6);
    let mut trainer = Trainer::new(
        Runtime::default_dir(),
        service.client(2),
        features,
        TrainerConfig { model: "sage".into(), lr: 0.1 },
        7,
    )?;
    println!(
        "model: GraphSAGE, {} parameters, batch {}, fanouts {:?}",
        trainer.params.num_parameters(),
        trainer.batch,
        trainer.fanouts
    );

    // 5. Train 20 mini-batches through the pipelined producer: sampling +
    //    feature assembly overlap the model step on background threads.
    let seeds: Vec<u32> = (0..4000).collect();
    let lab: Vec<u16> = seeds.iter().map(|&v| labels[v as usize]).collect();
    let mut batcher = Batcher::new(seeds, lab, trainer.batch, 5)?;
    let losses = trainer.train_pipelined(&mut batcher, 20, &PipelineConfig::default())?;
    println!("loss: first {:.4} -> last {:.4}", losses[0], losses.last().unwrap());

    // 6. Per-server workload: balanced thanks to vertex-cut + Gather-Apply.
    println!("server workload (edges scanned): {:?}", service.workload());
    service.shutdown();
    Ok(())
}
