//! Quickstart: the whole GLISP stack in ~60 lines.
//!
//! Generates a small power-law graph, partitions it with AdaDNE, launches
//! the Gather-Apply sampling service, trains a 3-layer GraphSAGE for 20
//! steps, and prints the loss. Runs out of the box on the pure-Rust
//! reference backend; after `make artifacts`, build with `--features
//! pjrt` to execute the AOT HLO artifacts on PJRT instead.
//!
//! Run: `cargo run --release --example quickstart`
//! Pool mode (R server workers per partition + sharded gathers — same
//! losses bit-for-bit, DESIGN.md §9):
//!      `cargo run --release --example quickstart -- --server-workers 4 --shard-size 16`

use std::sync::Arc;

use glisp::cli::Args;
use glisp::coordinator::{Batcher, FeatureStore, PipelineConfig, Trainer, TrainerConfig};
use glisp::graph::generator;
use glisp::partition::{quality, AdaDNE, Partitioner};
use glisp::runtime::Runtime;
use glisp::sampling::{SamplingService, ServiceConfig};
use glisp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    // 1. A labeled synthetic graph: 5k vertices, 60k edges, 8 communities.
    let mut rng = Rng::new(42);
    let g = generator::labeled_community_graph(5_000, 60_000, 8, 0.9, &mut rng);
    let labels = Arc::new(g.label.clone());
    println!("graph: {} vertices, {} edges", g.n, g.m());

    // 2. Vertex-cut partitioning with AdaDNE (the paper's contribution).
    //    --threads T runs the offline propose phase on T threads; the
    //    assignment is bit-identical for any value (DESIGN.md §10).
    let ea = AdaDNE {
        threads: args.get_usize("threads", 1),
        ..Default::default()
    }
    .partition(&g, 2, 1);
    let q = quality(&g, &ea);
    println!("AdaDNE: RF={:.3} VB={:.3} EB={:.3}", q.rf, q.vb, q.eb);

    // 3. Launch a sampling-server pool per partition (Gather-Apply);
    //    --server-workers / --shard-size only change throughput, never the
    //    sampled values (per-seed RNG streams).
    let svc_cfg = ServiceConfig::new(
        args.get_usize("server-workers", 1),
        args.get_usize("shard-size", 0),
    );
    let service = SamplingService::launch_cfg(&g, &ea, 1, svc_cfg)?;
    println!(
        "sampling: {} partitions x {} pool workers",
        service.partitions.len(),
        service.config.workers
    );

    // 4. A trainer wired to the AOT GraphSAGE train-step artifact.
    let features = FeatureStore::labeled(64, labels.clone(), 8, 0.6);
    let mut trainer = Trainer::new(
        Runtime::default_dir(),
        service.client(2),
        features,
        TrainerConfig { model: "sage".into(), lr: 0.1 },
        7,
    )?;
    println!(
        "model: GraphSAGE, {} parameters, batch {}, fanouts {:?}",
        trainer.params.num_parameters(),
        trainer.batch,
        trainer.fanouts
    );

    // 5. Train 20 mini-batches through the pipelined producer: sampling +
    //    feature assembly overlap the model step on background threads.
    let seeds: Vec<u32> = (0..4000).collect();
    let lab: Vec<u16> = seeds.iter().map(|&v| labels[v as usize]).collect();
    let mut batcher = Batcher::new(seeds, lab, trainer.batch, 5)?;
    let losses = trainer.train_pipelined(&mut batcher, 20, &PipelineConfig::default())?;
    println!("loss: first {:.4} -> last {:.4}", losses[0], losses.last().unwrap());

    // 6. Per-server workload: balanced thanks to vertex-cut + Gather-Apply.
    println!("server workload (edges scanned): {:?}", service.workload());
    if service.config.workers > 1 {
        println!("per-worker requests: {:?}", service.worker_requests());
    }
    service.shutdown();
    Ok(())
}
