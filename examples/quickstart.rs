//! Quickstart: the whole GLISP stack in ~60 lines.
//!
//! Generates a small power-law graph, partitions it with AdaDNE, launches
//! the Gather-Apply sampling service, trains a 3-layer GraphSAGE for 20
//! steps, and prints the loss. Runs out of the box on the pure-Rust
//! reference backend; after `make artifacts`, build with `--features
//! pjrt` to execute the AOT HLO artifacts on PJRT instead.
//!
//! Run: `cargo run --release --example quickstart`
//! Pool mode (R server workers per partition + sharded gathers — same
//! losses bit-for-bit, DESIGN.md §9):
//!      `cargo run --release --example quickstart -- --server-workers 4 --shard-size 16`
//! Multi-process mode (DESIGN.md §12) — same losses bit-for-bit again;
//! start one `glisp serve --graph quickstart --parts P --partition i
//! --listen ADDRi` process per partition, then:
//!      `cargo run --release --example quickstart -- --parts P --connect ADDR0,ADDR1[,...]`
//! (`--shutdown-remote` additionally stops the fleet on exit; the
//! `loss digest` line is the FNV-1a fingerprint CI diffs across modes.)

use std::sync::Arc;

use glisp::cli::Args;
use glisp::coordinator::{Batcher, FeatureStore, PipelineConfig, Trainer, TrainerConfig};
use glisp::graph::generator;
use glisp::partition::{quality, AdaDNE, Partitioner};
use glisp::runtime::Runtime;
use glisp::sampling::{SamplingService, ServiceConfig};
use glisp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    // 1. A labeled synthetic graph: 5k vertices, 60k edges, 8 communities.
    let mut rng = Rng::new(42);
    let g = generator::labeled_community_graph(5_000, 60_000, 8, 0.9, &mut rng);
    let labels = Arc::new(g.label.clone());
    println!("graph: {} vertices, {} edges", g.n, g.m());

    // 2. Vertex-cut partitioning with AdaDNE (the paper's contribution).
    //    --threads T runs the offline propose phase on T threads; the
    //    assignment is bit-identical for any value (DESIGN.md §10).
    let parts = args.get_usize("parts", 2);
    let svc_cfg = ServiceConfig::new(
        args.get_usize("server-workers", 1),
        args.get_usize("shard-size", 0),
    );
    // 3. A sampling service: either launch a server pool per partition in
    //    this process, or `--connect` to partitions already running as
    //    `glisp serve --graph quickstart` processes (DESIGN.md §12). The
    //    per-seed RNG streams make the sampled values — and the losses —
    //    bit-identical either way.
    let connect: Option<Vec<String>> = args
        .get("connect")
        .map(|v| v.split(',').filter(|a| !a.is_empty()).map(String::from).collect());
    let service = if let Some(addrs) = &connect {
        let service = SamplingService::connect(addrs, g.n, svc_cfg)?;
        println!("sampling: connected to {} partition server processes", addrs.len());
        service
    } else {
        let ea = AdaDNE {
            threads: args.get_usize("threads", 1),
            ..Default::default()
        }
        .partition(&g, parts, 1);
        let q = quality(&g, &ea);
        println!("AdaDNE: RF={:.3} VB={:.3} EB={:.3}", q.rf, q.vb, q.eb);
        let service = SamplingService::launch_cfg(&g, &ea, 1, svc_cfg)?;
        println!(
            "sampling: {} partitions x {} pool workers",
            service.num_partitions(),
            service.config.workers
        );
        service
    };

    // 4. A trainer wired to the AOT GraphSAGE train-step artifact.
    let features = FeatureStore::labeled(64, labels.clone(), 8, 0.6);
    let mut trainer = Trainer::new(
        Runtime::default_dir(),
        service.client(2),
        features,
        TrainerConfig { model: "sage".into(), lr: 0.1 },
        7,
    )?;
    println!(
        "model: GraphSAGE, {} parameters, batch {}, fanouts {:?}",
        trainer.params.num_parameters(),
        trainer.batch,
        trainer.fanouts
    );

    // 5. Train 20 mini-batches through the pipelined producer: sampling +
    //    feature assembly overlap the model step on background threads.
    let seeds: Vec<u32> = (0..4000).collect();
    let lab: Vec<u16> = seeds.iter().map(|&v| labels[v as usize]).collect();
    let mut batcher = Batcher::new(seeds, lab, trainer.batch, 5)?;
    let losses = trainer.train_pipelined(&mut batcher, 20, &PipelineConfig::default())?;
    println!("loss: first {:.4} -> last {:.4}", losses[0], losses.last().unwrap());
    // FNV-1a over the loss curve's f32 bits: CI diffs this line between the
    // in-process and --connect runs to prove wire-transport bit-identity.
    println!("loss digest: {:016x}", glisp::util::digest::f32_digest(&losses));

    // 6. Per-server workload: balanced thanks to vertex-cut + Gather-Apply.
    println!("server workload (edges scanned): {:?}", service.workload()?);
    if service.config.workers > 1 {
        println!("per-worker requests: {:?}", service.worker_requests()?);
    }
    if connect.is_some() && !args.has("shutdown-remote") {
        service.disconnect();
    } else {
        service.shutdown();
    }
    Ok(())
}
