//! Graph inference engine demo: full-graph vertex embedding + link
//! prediction, layerwise vs naive samplewise (paper Fig. 13), with the
//! two-level cache and PDS reordering active.
//!
//! Runs hermetically on the pure-Rust reference backend when `artifacts/`
//! is absent; build artifacts + enable `--features pjrt` for PJRT/XLA.
//!
//! Run: `cargo run --release --example inference_engine [-- --n 8000]`

use glisp::cli::Args;
use glisp::coordinator::{FeatureStore, PipelineConfig};
use glisp::graph::generator;
use glisp::inference::{
    init_decode_params, init_encoder_params, EngineConfig, LayerwiseEngine, SamplewiseRunner,
};
use glisp::partition::{AdaDNE, Partitioner};
use glisp::runtime::Runtime;
use glisp::util::rng::Rng;
use glisp::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 8_000);
    let parts = args.get_usize("parts", 4);

    let mut rng = Rng::new(1);
    let g = generator::chung_lu(n, n * 7, 2.1, &mut rng);
    let ea = AdaDNE::default().partition(&g, parts, 1);
    println!("graph: {} vertices, {} edges, {parts} partitions", g.n, g.m());

    let work = std::env::temp_dir().join("glisp_infer_example");
    let _ = std::fs::remove_dir_all(&work);
    let runtime = Runtime::load(Runtime::default_dir())?;
    println!("executor backend: {}", runtime.backend_name());
    let enc = init_encoder_params(&runtime, 3)?;

    // --- layerwise (the paper's engine) ---
    let mut engine = LayerwiseEngine::new(
        &g, &ea, runtime,
        FeatureStore::unlabeled(64),
        enc.clone(),
        EngineConfig::default(),
        work,
    )?;
    let t = Timer::start();
    let (h, rep) = engine.run_vertex_embedding()?;
    let lw = t.secs();
    println!(
        "[layerwise ] vertex embedding {lw:>7.2}s  computations={:<8} chunk reads={} \
         dyn hits={} (ratio {:.3})",
        rep.vertices_computed, rep.chunk_reads, rep.dynamic_hits, rep.dynamic_hit_ratio
    );

    // --- samplewise baseline ---
    let runtime2 = Runtime::load(Runtime::default_dir())?;
    let mut sw = SamplewiseRunner::new(&g, runtime2, FeatureStore::unlabeled(64), enc.clone(), 5)?;
    let t = Timer::start();
    let (_, swrep) = sw.run_vertex_embedding()?;
    let sws = t.secs();
    println!(
        "[samplewise] vertex embedding {sws:>7.2}s  computations={:<8}",
        swrep.vertices_computed
    );

    // --- samplewise again, batch assembly pipelined (DESIGN.md §7) ---
    let pcfg = PipelineConfig::default();
    let runtime3 = Runtime::load(Runtime::default_dir())?;
    let mut swp = SamplewiseRunner::new(&g, runtime3, FeatureStore::unlabeled(64), enc, 5)?;
    let t = Timer::start();
    let (_, prep) = swp.run_vertex_embedding_pipelined(&pcfg)?;
    let swp_s = t.secs();
    println!(
        "[samplewise] pipelined ({} producers) {swp_s:>7.2}s  computations={:<8} \
         ({:.2}x vs sync samplewise)",
        pcfg.producers,
        prep.vertices_computed,
        sws / swp_s
    );
    println!(
        "=> vertex-embedding speedup {:.2}x wall, {:.2}x compute\n",
        sws / lw,
        swrep.vertices_computed as f64 / rep.vertices_computed as f64
    );

    // --- link prediction on both paths ---
    let edges: Vec<(u32, u32)> = (0..g.n as u32)
        .filter(|&u| !g.out_neighbors(u).is_empty())
        .take(n / 4)
        .map(|u| (u, g.out_neighbors(u)[0]))
        .collect();
    let dec = init_decode_params(&engine.runtime, 9)?;
    let t = Timer::start();
    let (scores_lw, _) = engine.run_link_prediction(&h, &edges, &dec)?;
    let lw_lp = t.secs();
    let t = Timer::start();
    let (scores_sw, swrep2) = sw.run_link_prediction(&edges, &dec)?;
    let sw_lp = t.secs();
    println!(
        "[layerwise ] link prediction {lw_lp:>7.2}s over {} edges",
        edges.len()
    );
    println!(
        "[samplewise] link prediction {sw_lp:>7.2}s  computations={}",
        swrep2.vertices_computed
    );
    println!("=> link-prediction speedup {:.2}x wall", sw_lp / lw_lp);
    // Scores from both paths are probabilities on the same edges.
    assert_eq!(scores_lw.len(), scores_sw.len());
    Ok(())
}
