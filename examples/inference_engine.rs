//! Graph inference engine demo: full-graph vertex embedding + link
//! prediction, layerwise vs naive samplewise (paper Fig. 13), with the
//! two-level cache, PDS reordering and the worker-parallel K-slice sweep
//! (one thread per partition, DESIGN.md §8) active.
//!
//! Runs hermetically on the pure-Rust reference backend when `artifacts/`
//! is absent; build artifacts + enable `--features pjrt` for PJRT/XLA.
//!
//! Note on threading knobs: the training-side `--server-workers` /
//! `--shard-size` sampling-pool flags (quickstart, train_e2e, `glisp`
//! CLI; DESIGN.md §9) do not apply here — inference samples the local
//! graph directly, and its parallelism is the engine's one worker per
//! partition (`--parts`, `--seq` to force the sequential sweep,
//! DESIGN.md §8) plus `--producers` for the samplewise pipelined path.
//!
//! Run: `cargo run --release --example inference_engine [-- --n 8000
//!       --parts 4 --layers 3 --seq --layerwise-only --producers 2
//!       --evict fifo|lru --dyn-cache-frac 0.1]`

use glisp::cli::Args;
use glisp::coordinator::{FeatureStore, PipelineConfig};
use glisp::harness::infer_stack;
use glisp::inference::{init_decode_params, EngineConfig, EvictPolicy, SamplewiseRunner};
use glisp::runtime::Runtime;
use glisp::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 8_000);
    let parts = args.get_usize("parts", 4);
    let layers = args.get_usize("layers", 2);
    // --evict / --dyn-cache-frac: the hybrid cache's dynamic-tier knobs
    // (DESIGN.md §5) — watch the per-tier hit ratios move.
    let policy = match args.get_str("evict", "fifo") {
        "lru" => EvictPolicy::Lru,
        _ => EvictPolicy::Fifo,
    };
    let dyn_cache_frac = args.get_f64("dyn-cache-frac", 0.1);
    // --seq: single-threaded partition sweeps (the pre-parallel engine).
    let parallel = !args.has("seq");
    // --layerwise-only: skip the samplewise baselines (at K>=3 their
    // K-hop recomputation is orders of magnitude slower — that is the
    // paper's point, but not always worth the wall time here).
    let layerwise_only = args.has("layerwise-only");

    let work = std::env::temp_dir().join("glisp_infer_example");
    let mut stack = infer_stack(
        n,
        parts,
        &Runtime::default_dir(),
        work,
        EngineConfig {
            layers,
            parallel,
            policy,
            dyn_cache_frac,
            ..Default::default()
        },
    )?;
    let g = &stack.g;
    println!(
        "graph: {} vertices, {} edges, {parts} partitions, K={layers} \
         ({} sweep)",
        g.n,
        g.m(),
        if parallel { "parallel" } else { "sequential" }
    );
    println!("executor backend: {}", stack.engine.runtime.backend_name());

    // --- layerwise (the paper's engine) ---
    let t = Timer::start();
    let (h, rep) = stack.engine.run_vertex_embedding()?;
    let lw = t.secs();
    println!(
        "[layerwise ] vertex embedding {lw:>7.2}s  computations={:<8} chunk reads={} \
         dyn hits={} (ratio {:.3})",
        rep.vertices_computed, rep.chunk_reads, rep.dynamic_hits, rep.dynamic_hit_ratio
    );
    println!(
        "             per tier: static hit {:.3}, dynamic hit {:.3}, {} remote reads \
         (evict {policy:?}, dyn frac {dyn_cache_frac})",
        rep.static_hit_ratio(),
        rep.dynamic_hit_ratio,
        rep.remote_reads
    );
    for w in &rep.workers {
        if w.vertices_computed == 0 {
            continue;
        }
        println!(
            "             worker {:>2}: {:>7} vertices  fill {:>5} chunks ({:>6.3}s)  \
             model {:>6.2}s  dyn hit ratio {:.3}",
            w.worker,
            w.vertices_computed,
            w.fill_chunks,
            w.fill_secs,
            w.model_secs,
            w.dynamic_hit_ratio()
        );
    }

    // --- samplewise vertex-embedding baselines (skipped by
    //     --layerwise-only; the runner is reused for link prediction) ---
    let mut sw = if layerwise_only {
        None
    } else {
        let enc = stack.engine.enc_params.clone();
        let runtime2 = Runtime::load_with_layers(Runtime::default_dir(), layers)?;
        let mut sw =
            SamplewiseRunner::new(g, runtime2, FeatureStore::unlabeled(64), enc.clone(), 5)?;
        let t = Timer::start();
        let (_, swrep) = sw.run_vertex_embedding()?;
        let sws = t.secs();
        println!(
            "[samplewise] vertex embedding {sws:>7.2}s  computations={:<8}",
            swrep.vertices_computed
        );

        // Same again, batch assembly pipelined (DESIGN.md §7).
        let pcfg = PipelineConfig {
            producers: args.get_usize("producers", 2),
            ..Default::default()
        };
        let runtime3 = Runtime::load_with_layers(Runtime::default_dir(), layers)?;
        let mut swp = SamplewiseRunner::new(g, runtime3, FeatureStore::unlabeled(64), enc, 5)?;
        let t = Timer::start();
        let (_, prep) = swp.run_vertex_embedding_pipelined(&pcfg)?;
        let swp_s = t.secs();
        println!(
            "[samplewise] pipelined ({} producers) {swp_s:>7.2}s  computations={:<8} \
             ({:.2}x vs sync samplewise)",
            pcfg.producers,
            prep.vertices_computed,
            sws / swp_s
        );
        println!(
            "=> vertex-embedding speedup {:.2}x wall, {:.2}x compute\n",
            sws / lw,
            swrep.vertices_computed as f64 / rep.vertices_computed as f64
        );
        Some(sw)
    };

    // --- link prediction (layerwise always; samplewise for comparison) ---
    let edges: Vec<(u32, u32)> = (0..g.n as u32)
        .filter(|&u| !g.out_neighbors(u).is_empty())
        .take(n / 4)
        .map(|u| (u, g.out_neighbors(u)[0]))
        .collect();
    let dec = init_decode_params(&stack.engine.runtime, 9)?;
    let t = Timer::start();
    let (scores_lw, _) = stack.engine.run_link_prediction(&h, &edges, &dec)?;
    let lw_lp = t.secs();
    println!(
        "[layerwise ] link prediction {lw_lp:>7.2}s over {} edges",
        edges.len()
    );
    assert!(scores_lw.iter().all(|&s| (0.0..=1.0).contains(&s)));
    if let Some(sw) = sw.as_mut() {
        let t = Timer::start();
        let (scores_sw, swrep2) = sw.run_link_prediction(&edges, &dec)?;
        let sw_lp = t.secs();
        println!(
            "[samplewise] link prediction {sw_lp:>7.2}s  computations={}",
            swrep2.vertices_computed
        );
        println!("=> link-prediction speedup {:.2}x wall", sw_lp / lw_lp);
        // Scores from both paths are probabilities on the same edges.
        assert_eq!(scores_lw.len(), scores_sw.len());
    }
    Ok(())
}
