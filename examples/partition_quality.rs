//! Partition-quality explorer: run every partitioner in the suite over a
//! chosen synthetic dataset and partition count, printing the Table II
//! metrics plus the interior-vertex fraction that drives the inference
//! engine's static cache (Fig. 15a).
//!
//! Run: `cargo run --release --example partition_quality -- --dataset twitter-s --parts 8`

use glisp::cli::Args;
use glisp::graph::hetero::build_partitions;
use glisp::graph::{generator, metrics};
use glisp::harness::{f2, f3, Table};
use glisp::partition::{quality, AdaDNE, DistributedNE, EdgeCutLDG, Hash1D, Hash2D, Partitioner};
use glisp::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let name = args.get_str("dataset", "twitter-s");
    let parts = args.get_usize("parts", 8);
    let spec = generator::paper_datasets()
        .into_iter()
        .find(|d| d.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {name}"))?;
    let g = generator::generate(&spec, 1);
    let s = metrics::summarize(name, &g);
    println!(
        "dataset {}: {} vertices, {} edges, avg deg {:.1}, max deg {}, power-law: {}",
        s.name, s.n, s.m, s.avg_degree, s.max_degree, s.power_law
    );

    let algos: Vec<Box<dyn Partitioner>> = vec![
        Box::new(Hash1D),
        Box::new(Hash2D),
        Box::new(EdgeCutLDG::default()),
        Box::new(DistributedNE::default()),
        Box::new(AdaDNE::default()),
    ];
    let mut t = Table::new(
        &format!("{name} x {parts} partitions"),
        &["algorithm", "RF", "VB", "EB", "interior %", "time(s)"],
    );
    for p in algos {
        let timer = Timer::start();
        let ea = p.partition(&g, parts, 1);
        let secs = timer.secs();
        let q = quality(&g, &ea);
        let pgs = build_partitions(&g, &ea.part_of_edge, parts);
        let interior: usize = pgs.iter().map(|pg| pg.interior_count()).sum();
        let total: usize = pgs.iter().map(|pg| pg.nv()).sum();
        t.row(&[
            p.name().into(),
            f3(q.rf),
            f3(q.vb),
            f3(q.eb),
            f2(100.0 * interior as f64 / total as f64),
            f2(secs),
        ]);
    }
    t.print();
    Ok(())
}
