//! Partition-quality explorer: run every partitioner in the suite over a
//! chosen synthetic dataset and partition count, printing the Table II
//! metrics plus the interior-vertex fraction that drives the inference
//! engine's static cache (Fig. 15a).
//!
//! `--threads T` runs the neighbor-expansion propose phase and the
//! compact-structure build on T threads (DESIGN.md §10). The assignment is
//! bit-identical for any value — when T > 1 the explorer re-runs AdaDNE
//! serially and asserts it, printing both walls.
//!
//! Run: `cargo run --release --example partition_quality -- --dataset twitter-s --parts 8 --threads 4`

use glisp::cli::Args;
use glisp::graph::hetero::build_partitions_threads;
use glisp::graph::{generator, metrics};
use glisp::harness::{f2, f3, Table};
use glisp::partition::{quality, AdaDNE, DistributedNE, EdgeCutLDG, Hash1D, Hash2D, Partitioner};
use glisp::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let name = args.get_str("dataset", "twitter-s");
    let parts = args.get_usize("parts", 8);
    let threads = args.get_usize("threads", 1);
    let spec = generator::paper_datasets()
        .into_iter()
        .find(|d| d.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {name}"))?;
    let g = generator::generate(&spec, 1);
    let s = metrics::summarize(name, &g);
    println!(
        "dataset {}: {} vertices, {} edges, avg deg {:.1}, max deg {}, power-law: {}",
        s.name, s.n, s.m, s.avg_degree, s.max_degree, s.power_law
    );

    let algos: Vec<Box<dyn Partitioner>> = vec![
        Box::new(Hash1D),
        Box::new(Hash2D),
        Box::new(EdgeCutLDG::default()),
        Box::new(DistributedNE {
            threads,
            ..Default::default()
        }),
        Box::new(AdaDNE {
            threads,
            ..Default::default()
        }),
    ];
    let mut t = Table::new(
        &format!("{name} x {parts} partitions ({threads} offline threads)"),
        &["algorithm", "RF", "VB", "EB", "interior %", "partition(s)", "build(s)"],
    );
    // The AdaDNE row's assignment + wall, reused by the determinism check
    // below instead of re-running the parallel pass.
    let mut ada_run = None;
    for p in algos {
        let timer = Timer::start();
        let ea = p.partition(&g, parts, 1);
        let secs = timer.secs();
        if p.name() == "AdaDNE" {
            ada_run = Some((ea.clone(), secs));
        }
        let q = quality(&g, &ea);
        let timer = Timer::start();
        let pgs = build_partitions_threads(&g, &ea.part_of_edge, parts, threads)?;
        let build_secs = timer.secs();
        let interior: usize = pgs.iter().map(|pg| pg.interior_count()).sum();
        let total: usize = pgs.iter().map(|pg| pg.nv()).sum();
        t.row(&[
            p.name().into(),
            f3(q.rf),
            f3(q.vb),
            f3(q.eb),
            f2(100.0 * interior as f64 / total as f64),
            f2(secs),
            f2(build_secs),
        ]);
    }
    t.print();

    if threads > 1 {
        // Determinism contract (DESIGN.md §10): the parallel offline stage
        // must reproduce the serial schedule bit-for-bit. The parallel run
        // and its wall come from the table row above.
        let (parallel, par_secs) = ada_run.expect("AdaDNE is in the algo suite");
        let timer = Timer::start();
        let serial = AdaDNE::default().partition(&g, parts, 1);
        let serial_secs = timer.secs();
        assert_eq!(
            serial.part_of_edge, parallel.part_of_edge,
            "thread count leaked into the AdaDNE assignment"
        );
        println!(
            "AdaDNE determinism check: 1 thread {serial_secs:.2}s vs {threads} threads \
             {par_secs:.2}s ({:.2}x) — assignments bit-identical",
            serial_secs / par_secs.max(1e-9)
        );
    }
    Ok(())
}
