//! End-to-end training driver (the repo's e2e validation, EXPERIMENTS.md).
//!
//! Trains a 3-layer GraphSAGE (~110k params at the default width) on a
//! 20k-vertex / 240k-edge synthetic community graph for several hundred
//! steps through the full stack — AdaDNE partitioning → Gather-Apply
//! sampling servers → tree-format batches → AOT HLO train step on PJRT —
//! logging the loss curve and final test accuracy.
//!
//! Batches are produced by the pipelined producer by default (sampling +
//! feature assembly overlap the model step, DESIGN.md §7); pass `--sync`
//! for the strictly sequential path, `--producers N` / `--queue D` /
//! `--unordered` to tune the pipeline. `--server-workers R` launches an
//! R-worker pool per sampling partition and `--shard-size S` splits
//! gathers into S-seed shards the pool serves concurrently (DESIGN.md §9)
//! — pure throughput knobs, the loss curve is bit-identical.
//!
//! Runs hermetically on the pure-Rust reference backend when `artifacts/`
//! is absent; build artifacts + enable `--features pjrt` for PJRT/XLA.
//!
//! `--connect ADDR,ADDR,...` trains against partition servers running as
//! separate `glisp serve --graph train --n N --parts P` processes instead
//! of launching them in-process (DESIGN.md §12); the loss curve — see the
//! `loss digest` line — is bit-identical across the two deployments, which
//! the CI wire job asserts. `--shutdown-remote` stops the fleet on exit.
//!
//! Run: `cargo run --release --example train_e2e [-- --steps 300 --parts 4]`

use std::sync::Arc;

use glisp::cli::Args;
use glisp::coordinator::{Batcher, FeatureStore, PipelineConfig, Trainer, TrainerConfig};
use glisp::graph::generator;
use glisp::partition::{quality, AdaDNE, Partitioner};
use glisp::runtime::Runtime;
use glisp::sampling::{SamplingService, ServiceConfig};
use glisp::util::rng::Rng;
use glisp::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_usize("steps", 300);
    let parts = args.get_usize("parts", 4);
    let n = args.get_usize("n", 20_000);
    let classes = 8;
    let sync = args.has("sync");
    let pcfg = PipelineConfig {
        producers: args.get_usize("producers", 2),
        queue_depth: args.get_usize("queue", 2),
        ordered: !args.has("unordered"),
    };
    let svc_cfg = ServiceConfig::new(
        args.get_usize("server-workers", 1),
        args.get_usize("shard-size", 0),
    );

    println!("== GLISP end-to-end training driver ==");
    let t_total = Timer::start();

    // Dataset: labeled power-law-ish community graph.
    let mut rng = Rng::new(1);
    let g = generator::labeled_community_graph(n, n * 12, classes, 0.9, &mut rng);
    let labels = Arc::new(g.label.clone());
    println!("[data] {} vertices, {} edges, {} classes", g.n, g.m(), classes);

    // Partition + launch sampling service in-process, or --connect to a
    // fleet of `glisp serve --graph train` processes hosting the identical
    // stack (DESIGN.md §12). --threads T parallelizes the offline propose
    // phase; the assignment is bit-identical for any value (DESIGN.md §10).
    let connect: Option<Vec<String>> = args
        .get("connect")
        .map(|v| v.split(',').filter(|a| !a.is_empty()).map(String::from).collect());
    let service = if let Some(addrs) = &connect {
        let service = SamplingService::connect(addrs, g.n, svc_cfg)?;
        println!(
            "[sampling] connected to {} partition server processes: {addrs:?}",
            service.num_partitions()
        );
        service
    } else {
        let t = Timer::start();
        let threads = args.get_usize("threads", 1);
        let ea = AdaDNE {
            threads,
            ..Default::default()
        }
        .partition(&g, parts, 1);
        let q = quality(&g, &ea);
        println!(
            "[partition] AdaDNE {} parts in {:.2}s ({} threads): RF={:.3} VB={:.3} EB={:.3}",
            parts, t.secs(), threads, q.rf, q.vb, q.eb
        );
        let service = SamplingService::launch_cfg(&g, &ea, 1, svc_cfg)?;
        println!(
            "[sampling] {parts} partitions x {} pool workers{}",
            service.config.workers,
            if service.config.shard_size == usize::MAX {
                String::new()
            } else {
                format!(", gather shard size {}", service.config.shard_size)
            }
        );
        service
    };

    // Trainer.
    let features = FeatureStore::labeled(64, labels.clone(), classes, 0.6);
    let mut trainer = Trainer::new(
        Runtime::default_dir(),
        service.client(2),
        features,
        TrainerConfig { model: "sage".into(), lr: 0.1 },
        7,
    )?;
    println!(
        "[model] GraphSAGE-3L hidden=128: {} parameters, batch={}, fanouts={:?} ({} backend)",
        trainer.params.num_parameters(),
        trainer.batch,
        trainer.fanouts,
        trainer.runtime.backend_name()
    );
    if sync {
        println!("[mode] sync (sequential sample -> assemble -> execute)");
    } else {
        println!(
            "[mode] pipelined: {} producers, queue depth {}, {}",
            pcfg.producers,
            pcfg.queue_depth,
            if pcfg.ordered { "ordered (bit-exact vs sync)" } else { "unordered" }
        );
    }

    // 80/20 split.
    let split = (n * 8) / 10;
    let train_seeds: Vec<u32> = (0..split as u32).collect();
    let train_labels: Vec<u16> = train_seeds.iter().map(|&v| labels[v as usize]).collect();
    let mut batcher = Batcher::new(train_seeds, train_labels, trainer.batch, 5)?;

    // Train, logging every 20 steps.
    let t_train = Timer::start();
    let mut curve = Vec::new();
    let mut full_curve: Vec<f32> = Vec::new();
    for block in 0..steps.div_ceil(20) {
        let k = 20.min(steps - block * 20);
        let losses = if sync {
            trainer.train(&mut batcher, k)?
        } else {
            trainer.train_pipelined(&mut batcher, k, &pcfg)?
        };
        let mean: f32 = losses.iter().sum::<f32>() / losses.len() as f32;
        curve.push(mean);
        full_curve.extend_from_slice(&losses);
        println!("[train] step {:>4}  loss {:.4}", (block + 1) * 20, mean);
    }
    let train_secs = t_train.secs();
    // FNV-1a over every per-step loss's f32 bits — the cross-deployment
    // bit-equality witness the CI wire job diffs (DESIGN.md §12).
    println!("[train] loss digest: {:016x}", glisp::util::digest::f32_digest(&full_curve));
    println!(
        "[train] {steps} steps in {train_secs:.1}s = {:.2} steps/s ({:.0} seeds/s)",
        steps as f64 / train_secs,
        steps as f64 * trainer.batch as f64 / train_secs
    );
    assert!(
        curve.last().unwrap() < &(curve[0] * 0.9),
        "loss failed to decrease: {curve:?}"
    );

    // Test accuracy.
    let test_seeds: Vec<u32> = (split as u32..n as u32).collect();
    let test_labels: Vec<u16> = test_seeds.iter().map(|&v| labels[v as usize]).collect();
    let acc = trainer.evaluate(&test_seeds, &test_labels)?;
    println!("[eval] test accuracy {acc:.3} over {} vertices", test_seeds.len());
    assert!(acc > 1.5 / classes as f64, "accuracy no better than chance");

    println!("[workload] per-server edges scanned: {:?}", service.workload()?);
    if service.config.workers > 1 || connect.is_some() {
        println!(
            "[workload] per-worker requests (pool attribution): {:?}",
            service.worker_requests()?
        );
    }
    println!("== done in {:.1}s ==", t_total.secs());
    if connect.is_some() && !args.has("shutdown-remote") {
        service.disconnect();
    } else {
        service.shutdown();
    }
    Ok(())
}
